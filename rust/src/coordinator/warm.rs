//! Persistent warm-start cache for the mapping service.
//!
//! Solved results outlive the process: the service loads this store at
//! spawn and flushes it periodically while running (the crash-safe flush,
//! DESIGN.md §12) and once more when the worker pool exits, so repeated
//! CLI/eval runs against the same `--cache-dir` answer without re-solving
//! — the "same (workload, hardware) pairs recur across runs" serving
//! pattern. With a cache byte budget configured, every flush also
//! compacts the file to the cap, dropping least-recently-merged entries
//! first — the disk tier is bounded like the RAM tier, and eviction only
//! ever costs a future re-solve, never an answer change.
//!
//! **Format v6** (`warm_cache_v6.tsv` inside the cache dir): a header line
//! ([`WARM_CACHE_HEADER`]) followed by one TSV entry per solve key. Keys
//! are the 64-bit solve fingerprints of
//! [`super::service::solve_fingerprint`] — shape, *full* architecture
//! parameter set, solver options, and format version; never an arch name.
//! Every entry additionally records its
//! [`super::service::arch_options_fingerprint`] (the shape-independent
//! half of the key), so a fresh service can harvest the persisted winning
//! mappings as cross-shape seed **donors** for other fingerprints on the
//! same architecture (DESIGN.md §6) — the reason v2 was bumped. v4 tracked
//! the bound-ordered engine (DESIGN.md §8: reordered-scan counters plus
//! the unit-level skip counters); v5 added the distributed-solve
//! provenance counters (`shards`/`shard_retries`, DESIGN.md §10); v6 adds
//! the supervision counters (`shard_respawns`/`breaker_trips`, DESIGN.md
//! §13) to the persisted certificate, so v5 entries no longer carry the
//! full certificate — they are rejected wholesale by the header, like
//! every prior version. Every
//! `f64` is serialized as its IEEE-754 bit pattern in hex (`to_bits`), so
//! a warm result is **bit-identical** to the original solve. Infeasible
//! outcomes persist too (`err` lines): the negative cache is as warm as
//! the positive one.
//!
//! **Invalidation rules** are by construction, not by deletion:
//! * any change to the shape, arch parameters, or solver options changes
//!   the fingerprint, so stale entries are simply never looked up;
//! * bumping [`super::service::CACHE_FORMAT_VERSION`] changes both the
//!   header (whole-file rejection) and every fingerprint;
//! * a file with an unknown header is ignored wholesale (start cold);
//! * individually corrupt or truncated lines (e.g. a killed process mid
//!   write, despite the tmp-file + rename flush) are skipped one by one —
//!   every intact entry survives.

use crate::mapping::{Axis, Bypass, Mapping, Tile};
use crate::solver::{Certificate, SolveError, SolveResult};
use crate::util::fault::{self, Fault};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// First line of every store file; the version must match exactly. Kept in
/// lockstep with [`super::service::CACHE_FORMAT_VERSION`] so a version
/// bump really does reject old files wholesale (v6: the certificate
/// gained the supervision provenance counters
/// `shard_respawns`/`breaker_trips`, DESIGN.md §13).
pub const WARM_CACHE_HEADER: &str = "# goma-warm-cache v6";

/// File name of the store inside a service's `--cache-dir` (versioned in
/// lockstep with the header: a pre-bump file is simply never opened).
pub const WARM_CACHE_FILE: &str = "warm_cache_v6.tsv";

/// One persisted outcome: the solve succeeded (full result) or proved the
/// key infeasible (negative entry).
pub type WarmOutcome = Result<Arc<SolveResult>, SolveError>;

/// One persisted store entry: the outcome plus the shape-independent
/// [`super::service::arch_options_fingerprint`] of the solve that produced
/// it — the grouping key the seeding planner uses to collect donor
/// mappings for *other* shapes on the same architecture.
#[derive(Clone)]
pub struct WarmEntry {
    pub arch_fp: u64,
    pub outcome: WarmOutcome,
}

/// The merged view the store flushes from: every entry carries a
/// monotonically increasing merge sequence number — the compaction
/// recency. Re-merging a fingerprint refreshes its seq, so under a size
/// cap the entries dropped first are the least recently (re)proved ones.
struct MergedMap {
    entries: HashMap<u64, (WarmEntry, u64)>,
    next_seq: u64,
}

/// The shared on-disk store: loaded once at service spawn; the dispatcher
/// merges newly proved outcomes back in — periodically (the crash-safe
/// flush, DESIGN.md §12) and once more at pool exit — and each flush
/// rewrites the file atomically (unique tmp file + rename). The merged
/// view starts as the loaded set, so a partial flush (periodic flushes
/// carry only the new window) still writes the full union — flushing can
/// never lose entries that were on disk at open.
pub struct WarmStore {
    path: Option<PathBuf>,
    /// On-disk byte cap applied at every flush ([`WarmStore::merge_and_flush`]):
    /// oldest-merged entries are compacted away until the serialized file
    /// fits. `None` = grow forever (the pre-cap behavior).
    cap_bytes: Option<u64>,
    loaded: HashMap<u64, WarmEntry>,
    merged: Mutex<MergedMap>,
}

impl WarmStore {
    /// Open the store under `dir` (`None` disables persistence). A missing,
    /// version-mismatched, or unreadable file is not an error — recovery is
    /// "start cold". `cap_bytes` bounds the serialized file size on flush.
    pub fn open(dir: Option<PathBuf>, cap_bytes: Option<u64>) -> WarmStore {
        let path = dir.map(|d| d.join(WARM_CACHE_FILE));
        let loaded = match &path {
            Some(p) => load_file(p),
            None => HashMap::new(),
        };
        // Seed the merged view from the loaded set in fingerprint order:
        // deterministic seqs, so which loaded entries a cap retains is a
        // pure function of the file contents.
        let mut keys: Vec<u64> = loaded.keys().copied().collect();
        keys.sort_unstable();
        let mut merged = MergedMap { entries: HashMap::new(), next_seq: 0 };
        for fp in keys {
            let seq = merged.next_seq;
            merged.next_seq += 1;
            merged.entries.insert(fp, (loaded[&fp].clone(), seq));
        }
        WarmStore {
            path,
            cap_bytes,
            merged: Mutex::new(merged),
            loaded,
        }
    }

    /// Entries present on disk at open time (handed to the cache shards).
    pub fn loaded(&self) -> impl Iterator<Item = (u64, WarmEntry)> + '_ {
        self.loaded.iter().map(|(&fp, v)| (fp, v.clone()))
    }

    /// Number of entries loaded at open time.
    pub fn loaded_len(&self) -> usize {
        self.loaded.len()
    }

    /// Merge `entries` into the store and rewrite the file. The dispatcher
    /// calls this with each flushed window of newly proved outcomes (and
    /// once more at pool exit); the merged view already carries the loaded
    /// set plus every earlier window, so each flush writes the full union.
    /// With a `cap_bytes`, oldest-merged entries are compacted away first
    /// until the serialized file fits the cap. A store without a path
    /// merges in memory only.
    ///
    /// The merge into the RAM view happens *before* (and regardless of)
    /// the file write, so a failed flush — disk full, torn tmp file —
    /// loses nothing: the entries stay merged, and the next successful
    /// flush writes the full union. The error is returned so the service
    /// can count it and enter degraded (RAM-only) mode (DESIGN.md §13);
    /// the on-disk file is never left corrupt (tmp + rename).
    pub fn merge_and_flush(
        &self,
        entries: impl IntoIterator<Item = (u64, WarmEntry)>,
    ) -> std::io::Result<()> {
        let mut merged = self.merged.lock().unwrap();
        for (fp, v) in entries {
            let seq = merged.next_seq;
            merged.next_seq += 1;
            merged.entries.insert(fp, (v, seq));
        }
        if let Some(cap) = self.cap_bytes {
            compact(&mut merged, cap);
        }
        match &self.path {
            Some(path) => write_file(path, &merged.entries),
            None => Ok(()),
        }
    }
}

/// Drop lowest-seq (least recently merged) entries until the serialized
/// file — header plus one line per entry, each with its trailing newline —
/// fits `cap`. Exact byte accounting: sizes come from the same
/// [`entry_line`] the writer emits.
fn compact(merged: &mut MergedMap, cap: u64) {
    let mut total = WARM_CACHE_HEADER.len() as u64 + 1;
    let mut sized: Vec<(u64, u64, u64)> = merged
        .entries
        .iter()
        .map(|(&fp, (e, seq))| (*seq, fp, entry_line(fp, e).len() as u64 + 1))
        .collect();
    total += sized.iter().map(|&(_, _, b)| b).sum::<u64>();
    if total <= cap {
        return;
    }
    sized.sort_unstable_by_key(|&(seq, _, _)| seq);
    for (_, fp, bytes) in sized {
        if total <= cap {
            break;
        }
        merged.entries.remove(&fp);
        total -= bytes;
    }
}

fn load_file(path: &Path) -> HashMap<u64, WarmEntry> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return HashMap::new();
    };
    let mut lines = text.lines();
    if lines.next() != Some(WARM_CACHE_HEADER) {
        // Unknown version or foreign file: reject wholesale rather than
        // guess at a layout that may have changed meaning.
        return HashMap::new();
    }
    let mut out = HashMap::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        if let Some((fp, v)) = parse_line(line) {
            out.insert(fp, v);
        }
    }
    out
}

/// One serialized store line (no trailing newline) — shared by the writer
/// and the compaction size accounting, so "fits the cap" is measured in
/// the exact bytes the file will contain.
fn entry_line(fp: u64, e: &WarmEntry) -> String {
    let afp = e.arch_fp;
    match &e.outcome {
        Err(_) => format!("{fp:016x}\terr\t{afp:016x}\tinfeasible"),
        Ok(r) => format!("{fp:016x}\tok\t{afp:016x}\t{}", format_result(r.as_ref())),
    }
}

fn write_file(path: &Path, entries: &HashMap<u64, (WarmEntry, u64)>) -> std::io::Result<()> {
    use std::fmt::Write as _;
    use std::sync::atomic::{AtomicU64, Ordering};
    // Unique per writer: concurrent flushes into one shared cache dir (two
    // processes, or two services in one process) must not interleave on a
    // common tmp path — last rename wins with an intact file either way.
    static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension(format!(
        "tsv.tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let mut text = String::new();
    let _ = writeln!(text, "{WARM_CACHE_HEADER}");
    // Sorted keys: deterministic file contents for a given entry set.
    let mut keys: Vec<u64> = entries.keys().copied().collect();
    keys.sort_unstable();
    for fp in keys {
        let (e, _) = &entries[&fp];
        let _ = writeln!(text, "{}", entry_line(fp, e));
    }
    // Chaos site `warm.flush.write`: the injected failure modes of the
    // *tmp-file* write. `err:enospc` is the degraded-mode trigger; `torn`
    // leaves a truncated tmp behind and fails before the rename, which is
    // exactly why the real file can never be corrupted by a died flush.
    match fault::hit("warm.flush.write") {
        None => {}
        Some(Fault::Delay(d)) => std::thread::sleep(d),
        Some(Fault::Kill) => std::process::exit(fault::KILL_EXIT_CODE),
        Some(Fault::Err(flavor)) => return Err(fault::flavor_error(flavor)),
        Some(Fault::Torn(keep)) => {
            std::fs::write(&tmp, &text.as_bytes()[..keep.min(text.len())])?;
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "injected torn write: tmp file truncated before rename",
            ));
        }
        Some(Fault::Corrupt) => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "injected corruption",
            ))
        }
    }
    std::fs::write(&tmp, &text)?;
    std::fs::rename(&tmp, path)
}

/// Hex IEEE-754 bit pattern: the exact-round-trip float encoding.
fn fx(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn hex_u64(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

fn hex_f64(s: &str) -> Option<f64> {
    Some(f64::from_bits(hex_u64(s)?))
}

fn axis_of(s: &str) -> Option<Axis> {
    match s {
        "x" => Some(Axis::X),
        "y" => Some(Axis::Y),
        "z" => Some(Axis::Z),
        _ => None,
    }
}

fn bypass_of(s: &str) -> Option<Bypass> {
    Bypass::from_bits(s.parse::<u8>().ok()?)
}

/// The 34 payload fields of an `ok` line (following the fingerprint, the
/// kind tag, and the arch/options fingerprint), tab-joined: 9 tile
/// lengths, the two walking axes, the two bypass bitmasks, the 7 energy
/// terms, the certificate (3 bounds, 9 counters, proved bit), and the
/// solve time.
fn format_result(r: &SolveResult) -> String {
    let m = &r.mapping;
    let e = &r.energy;
    let c = &r.certificate;
    format!(
        "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t\
         {}\t{}\t{}\t{}\t{}\t{}\t{}\t\
         {}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
        m.l1.x,
        m.l1.y,
        m.l1.z,
        m.l2.x,
        m.l2.y,
        m.l2.z,
        m.l3.x,
        m.l3.y,
        m.l3.z,
        m.alpha01,
        m.alpha12,
        m.b1.bits(),
        m.b3.bits(),
        fx(e.src1),
        fx(e.src3),
        fx(e.src4),
        fx(e.compute),
        fx(e.leakage),
        fx(e.normalized),
        fx(e.total_pj),
        fx(c.upper_bound),
        fx(c.lower_bound),
        fx(c.gap),
        c.nodes,
        c.combos_total,
        c.combos_pruned,
        c.units_total,
        c.units_skipped,
        c.shards,
        c.shard_retries,
        c.shard_respawns,
        c.breaker_trips,
        c.proved_optimal as u8,
        fx(r.solve_time.as_secs_f64()),
    )
}

/// Parse one entry line; `None` on any malformation (the caller skips it).
fn parse_line(line: &str) -> Option<(u64, WarmEntry)> {
    let f: Vec<&str> = line.split('\t').collect();
    let fp = hex_u64(f.first()?)?;
    let kind = *f.get(1)?;
    let arch_fp = hex_u64(f.get(2)?)?;
    match kind {
        "err" => {
            if f.len() != 4 || f[3] != "infeasible" {
                return None;
            }
            Some((fp, WarmEntry { arch_fp, outcome: Err(SolveError::NoFeasibleMapping) }))
        }
        "ok" => {
            if f.len() != 37 {
                return None;
            }
            let t = |i: usize| f[3 + i].parse::<u64>().ok();
            let mapping = Mapping {
                l1: Tile::new(t(0)?, t(1)?, t(2)?),
                l2: Tile::new(t(3)?, t(4)?, t(5)?),
                l3: Tile::new(t(6)?, t(7)?, t(8)?),
                alpha01: axis_of(f[12])?,
                alpha12: axis_of(f[13])?,
                b1: bypass_of(f[14])?,
                b3: bypass_of(f[15])?,
            };
            let energy = crate::energy::EnergyBreakdown {
                src1: hex_f64(f[16])?,
                src3: hex_f64(f[17])?,
                src4: hex_f64(f[18])?,
                compute: hex_f64(f[19])?,
                leakage: hex_f64(f[20])?,
                normalized: hex_f64(f[21])?,
                total_pj: hex_f64(f[22])?,
            };
            let certificate = Certificate {
                upper_bound: hex_f64(f[23])?,
                lower_bound: hex_f64(f[24])?,
                gap: hex_f64(f[25])?,
                nodes: f[26].parse().ok()?,
                combos_total: f[27].parse().ok()?,
                combos_pruned: f[28].parse().ok()?,
                units_total: f[29].parse().ok()?,
                units_skipped: f[30].parse().ok()?,
                shards: f[31].parse().ok()?,
                shard_retries: f[32].parse().ok()?,
                shard_respawns: f[33].parse().ok()?,
                breaker_trips: f[34].parse().ok()?,
                proved_optimal: match f[35] {
                    "1" => true,
                    "0" => false,
                    _ => return None,
                },
            };
            let solve_time = Duration::try_from_secs_f64(hex_f64(f[36])?).ok()?;
            Some((
                fp,
                WarmEntry {
                    arch_fp,
                    outcome: Ok(Arc::new(SolveResult {
                        mapping,
                        energy,
                        certificate,
                        solve_time,
                    })),
                },
            ))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Accelerator;
    use crate::mapping::GemmShape;
    use crate::solver::{solve, SolverOptions};

    fn solved() -> SolveResult {
        let arch = Accelerator::custom("warmfmt", 1 << 16, 16, 64);
        solve(GemmShape::new(64, 96, 32), &arch, SolverOptions::default()).unwrap()
    }

    #[test]
    fn line_round_trip_is_bit_exact() {
        let r = solved();
        let line = format!("{:016x}\tok\t{:016x}\t{}", 0xDEADBEEFu64, 0xA5C4u64, format_result(&r));
        let (fp, back) = parse_line(&line).expect("own format must parse");
        assert_eq!(back.arch_fp, 0xA5C4);
        let back = back.outcome.unwrap();
        assert_eq!(fp, 0xDEADBEEF);
        assert_eq!(back.mapping, r.mapping);
        assert_eq!(back.energy.normalized.to_bits(), r.energy.normalized.to_bits());
        assert_eq!(back.energy.total_pj.to_bits(), r.energy.total_pj.to_bits());
        assert_eq!(
            back.certificate.upper_bound.to_bits(),
            r.certificate.upper_bound.to_bits()
        );
        assert_eq!(back.certificate.nodes, r.certificate.nodes);
        assert_eq!(back.certificate.units_total, r.certificate.units_total);
        assert_eq!(back.certificate.units_skipped, r.certificate.units_skipped);
        assert_eq!(back.certificate.shards, r.certificate.shards);
        assert_eq!(back.certificate.shard_retries, r.certificate.shard_retries);
        assert_eq!(back.certificate.shard_respawns, r.certificate.shard_respawns);
        assert_eq!(back.certificate.breaker_trips, r.certificate.breaker_trips);
        assert_eq!(back.certificate.proved_optimal, r.certificate.proved_optimal);
        assert_eq!(
            back.solve_time.as_secs_f64().to_bits(),
            r.solve_time.as_secs_f64().to_bits()
        );
    }

    #[test]
    fn err_line_round_trips() {
        let (fp, v) = parse_line("00000000000000aa\terr\t00000000000000bb\tinfeasible").unwrap();
        assert_eq!(fp, 0xaa);
        assert_eq!(v.arch_fp, 0xbb);
        assert_eq!(v.outcome.unwrap_err(), SolveError::NoFeasibleMapping);
    }

    #[test]
    fn malformed_lines_are_rejected_not_panicked() {
        let r = solved();
        let good = format!("{:016x}\tok\t{:016x}\t{}", 1u64, 2u64, format_result(&r));
        // Overflowing integer field + field count off by one.
        let overflow = good.replace("\tok\t", "\tok\t99999999999999999999\t");
        // A corrupt mapping field (non-numeric tile length).
        let corrupt_mapping = {
            let mut f: Vec<&str> = good.split('\t').collect();
            f[3] = "x9";
            f.join("\t")
        };
        for bad in [
            "",
            "garbage",
            "zz\terr\t00bb\tinfeasible",
            "01\terr\t00bb\tsomething-else",
            "01\terr\tinfeasible",                      // v2-shaped err line: no arch fp
            "01\tok\t00bb\tnot-enough-fields",
            "01\twat\t00bb\tinfeasible",
            &good[..good.len() / 2], // truncated mid write
            overflow.as_str(),
            corrupt_mapping.as_str(),
        ] {
            assert!(parse_line(bad).is_none(), "accepted malformed line: {bad:?}");
        }
        assert!(parse_line(&good).is_some());
    }

    #[test]
    fn store_rejects_unknown_version_wholesale() {
        let dir = std::env::temp_dir().join(format!("goma_warm_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(WARM_CACHE_FILE);
        for old in [
            "# goma-warm-cache v0\n00aa\terr\tinfeasible\n",
            // A v2-era store: rejected by its header before any line parse.
            "# goma-warm-cache v2\n00aa\terr\tinfeasible\n",
            // A v3-era store (pre-bound-order counters): likewise.
            "# goma-warm-cache v3\n00aa\terr\t00bb\tinfeasible\n",
            // A v4-era store (pre-shard-counter certificate): likewise.
            "# goma-warm-cache v4\n00aa\terr\t00bb\tinfeasible\n",
            // A v5-era store (pre-supervision-counter certificate): likewise.
            "# goma-warm-cache v5\n00aa\terr\t00bb\tinfeasible\n",
        ] {
            std::fs::write(&path, old).unwrap();
            let store = WarmStore::open(Some(dir.clone()), None);
            assert_eq!(store.loaded_len(), 0, "pre-v6 file must be ignored wholesale: {old:?}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flush_preserves_loaded_entries_across_partial_merges() {
        let dir = std::env::temp_dir().join(format!("goma_warm_partial_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join(WARM_CACHE_FILE)).ok();
        let a = WarmEntry { arch_fp: 1, outcome: Err(SolveError::NoFeasibleMapping) };
        let s1 = WarmStore::open(Some(dir.clone()), None);
        s1.merge_and_flush([(0xaa, a.clone())]).unwrap();
        // A later process merges only its own new window: the flush must
        // carry the union (regression: `merged` used to start empty, so a
        // flush that was not preceded by re-merging every shard silently
        // dropped the loaded set from the rewritten file).
        let s2 = WarmStore::open(Some(dir.clone()), None);
        assert_eq!(s2.loaded_len(), 1);
        s2.merge_and_flush([(0xbb, a.clone())]).unwrap();
        let s3 = WarmStore::open(Some(dir.clone()), None);
        assert_eq!(s3.loaded_len(), 2, "a partial flush must keep the loaded entries");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_flush_keeps_disk_intact_and_ram_merged() {
        let _serial = fault::test_guard();
        let dir = std::env::temp_dir().join(format!("goma_warm_fail_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(WARM_CACHE_FILE);
        std::fs::remove_file(&path).ok();
        let e = |afp| WarmEntry { arch_fp: afp, outcome: Err(SolveError::NoFeasibleMapping) };
        let store = WarmStore::open(Some(dir.clone()), None);
        store.merge_and_flush([(1, e(1))]).unwrap();
        let good = std::fs::read_to_string(&path).unwrap();

        // Flush 1 hits injected ENOSPC, flush 2 a torn tmp write; both
        // fail, and the real file must still carry exactly the last good
        // contents — the tmp+rename discipline at work.
        fault::install("9:warm.flush.write=err:enospc@0;warm.flush.write=torn:10@1").unwrap();
        let r = store.merge_and_flush([(2, e(2))]);
        assert_eq!(r.unwrap_err().kind(), std::io::ErrorKind::StorageFull);
        assert!(store.merge_and_flush([(3, e(3))]).is_err());
        fault::clear();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), good);

        // The failed windows stayed merged in RAM: the next successful
        // flush writes the full union, losing nothing.
        store.merge_and_flush(std::iter::empty()).unwrap();
        let back = WarmStore::open(Some(dir.clone()), None);
        assert_eq!(back.loaded_len(), 3, "failed flushes must not lose entries");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_cap_compacts_oldest_merged_entries_first() {
        let dir = std::env::temp_dir().join(format!("goma_warm_cap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(WARM_CACHE_FILE);
        std::fs::remove_file(&path).ok();
        let e = |afp| WarmEntry { arch_fp: afp, outcome: Err(SolveError::NoFeasibleMapping) };
        // Exactly two err lines fit under the cap.
        let line = entry_line(1, &e(1)).len() as u64 + 1;
        let cap = WARM_CACHE_HEADER.len() as u64 + 1 + 2 * line;
        let store = WarmStore::open(Some(dir.clone()), Some(cap));
        store.merge_and_flush([(1, e(1))]).unwrap();
        store.merge_and_flush([(2, e(2)), (3, e(3))]).unwrap();
        assert!(std::fs::metadata(&path).unwrap().len() <= cap, "file must fit the cap");
        let back = WarmStore::open(Some(dir.clone()), Some(cap));
        let kept: Vec<u64> = back.loaded().map(|(fp, _)| fp).collect();
        assert_eq!(kept.len(), 2);
        assert!(!kept.contains(&1), "the oldest-merged entry is the one compacted");
        assert!(kept.contains(&2) && kept.contains(&3));
        // Re-merging a key refreshes its recency: after touching 2, adding
        // 4 compacts 3 away, not 2.
        back.merge_and_flush([(2, e(2))]).unwrap();
        back.merge_and_flush([(4, e(4))]).unwrap();
        let last = WarmStore::open(Some(dir.clone()), Some(cap));
        let kept: Vec<u64> = last.loaded().map(|(fp, _)| fp).collect();
        assert!(kept.contains(&2) && kept.contains(&4) && !kept.contains(&3), "{kept:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
