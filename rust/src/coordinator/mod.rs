//! L3 coordinator: the async mapping service.
//!
//! GOMA's headline capability is real-time mapping — sub-second optimal
//! solves (§V-C1: 0.65 s geomean per GEMM) make it deployable *online*, at
//! model-compile or request time. The coordinator packages the solver as a
//! long-running service in the style of an inference router: an async
//! request queue, de-duplication of identical in-flight requests, a result
//! cache keyed by `(GEMM shape, accelerator)`, and service metrics. The
//! compiled-artifact execution path ([`crate::runtime`]) hangs off the same
//! event loop, so a request can go mapping → (optionally) execution without
//! Python anywhere on the path.

mod service;

pub use service::{MappingService, ServiceHandle, ServiceMetrics};
