//! L3 coordinator: the sharded mapping service.
//!
//! GOMA's headline capability is real-time mapping — sub-second optimal
//! solves (§V-C1: 0.65 s geomean per GEMM) make it deployable *online*, at
//! model-compile or request time — and the Turbo-Charged-Mapper framing
//! treats fast-and-optimal mapping as a *serving* problem: the same
//! (workload, hardware) pairs recur across runs. The coordinator packages
//! the solver accordingly, as a long-running service in the style of an
//! inference router:
//!
//! * **a sharded result cache** — keyed by a stable 64-bit *solve
//!   fingerprint* ([`solve_fingerprint`]) covering the GEMM shape, the full
//!   architecture parameter set (never the arch name), the solver options,
//!   and the cache format version; hash-partitioned `fp % shards` with
//!   per-shard hit metrics, a byte budget with LRU eviction, and a
//!   bloom-filter front per shard (`--cache-budget-bytes` /
//!   `GOMA_CACHE_BUDGET`; eviction is answer-invisible, DESIGN.md §12);
//! * **an N-worker solve pool** — distinct uncached keys in each batch
//!   window fan out onto [`crate::util::parallel::ordered_map`]'s scoped
//!   worker pool ([`MappingService::with_workers`]); duplicate in-flight
//!   requests coalesce into one solve, and infeasible outcomes are cached
//!   negatively so they never re-run;
//! * **a persistent warm-start store** — with
//!   [`MappingService::with_cache_dir`], solved results serialize
//!   bit-exactly to a versioned on-disk TSV (see [`WARM_CACHE_FILE`] /
//!   [`WARM_CACHE_HEADER`]) loaded at spawn, flushed periodically while
//!   running (crash-safe: a SIGKILL loses at most the last window) and on
//!   [`ServiceHandle::shutdown`], and compacted to the cache byte budget
//!   on every flush, so repeated CLI/eval runs are warm across processes;
//! * **batch submission** — [`ServiceHandle::submit_batch`] /
//!   [`ServiceHandle::map_workload`] push a whole workload's GEMMs in one
//!   call, the request-path pattern a compiler or serving stack would use;
//! * **cross-shape warm bounds** — batch misses are ordered by shape
//!   similarity and solved in waves, each seeded with the tightest valid
//!   re-costed bound from already-solved mappings on the same architecture
//!   (earlier waves of the batch, plus warm-store entries under *other*
//!   fingerprints — grouped by [`arch_options_fingerprint`]). Provably
//!   harmless: mappings and energies stay bit-identical, node counts only
//!   shrink (DESIGN.md §6; `--seed-bounds` / `GOMA_SEED_BOUNDS` to toggle).
//!
//! * **a network front door** — [`MappingServer`] puts a dependency-free
//!   HTTP/JSON wire protocol ([`wire`]) in front of the service:
//!   admission control keyed off the `queue_depth` gauge (overload sheds
//!   with a retryable `503` instead of queueing), per-client in-flight
//!   quotas, per-request deadlines mapped onto the engine's wall-clock
//!   budget net of queueing time, and a Prometheus `/metrics` endpoint.
//!   Wire answers are bit-identical to in-process
//!   [`ServiceHandle::submit_batch`] answers (the wire serializes floats
//!   by bit pattern), proven by `rust/tests/server.rs`. The matching
//!   client side is [`WireClient`] (`goma solve --remote`): phased
//!   deadline-aware retries with jittered backoff on sheds and connect
//!   failures, never retrying once a `200` body has begun (DESIGN.md
//!   §13).
//!
//! The compiled-artifact execution path ([`crate::runtime`]) hangs off the
//! same process, so a request can go mapping → (optionally) execution
//! without Python anywhere on the path.

mod cache;
pub mod client;
mod server;
mod service;
mod warm;
pub mod wire;

pub use client::{ClientError, ClientOptions, WireClient};
pub use server::{MappingServer, ServeOptions, ServerHandle, ServerMetrics};
pub use service::{
    arch_options_fingerprint, shape_fingerprint, solve_fingerprint, MappingService, Pending,
    ServiceHandle, ServiceMetrics, CACHE_FORMAT_VERSION,
};
pub use warm::{WarmEntry, WarmOutcome, WarmStore, WARM_CACHE_FILE, WARM_CACHE_HEADER};
