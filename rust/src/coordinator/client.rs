//! A retrying wire client for [`super::MappingServer`] (DESIGN.md §13).
//!
//! [`super::wire::http_call`] is a one-shot protocol helper: an IO error
//! tells the caller nothing about *when* the call died, so it cannot
//! safely retry. This client phases the call — connect, send, status
//! line, headers, body — and derives its retry policy from the phase:
//!
//! * **Before any reply byte** (connect refused/reset, send failure, a
//!   dead socket at the status line) the request provably went
//!   unanswered, and re-submitting is idempotent by the bit-identity
//!   contract: the server's answer for a key is the same bits no matter
//!   which replica, route, or retry produces it, and sheds/cache hits
//!   make duplicate submissions harmless. Retry with jittered
//!   exponential backoff.
//! * **Sheds** (`503` overload / `429` quota) are explicit "not an
//!   answer, try again" refusals — retryable by design (DESIGN.md §9).
//! * **After a `200` status line** the answer has begun. A failure here
//!   ([`ClientError::TornReply`]) is *never* retried: the request *was*
//!   answered — the bytes just didn't survive the socket — and the
//!   caller, not this layer, must decide whether to re-issue it as a new
//!   request.
//! * **Definitive verdicts** — `422` solver errors, `400` rejections —
//!   are answers, not failures; retrying cannot change them.
//!
//! Backoff is seeded ([`ClientOptions::seed`]) so tests and the chaos
//! sweep get reproducible retry schedules, and deadline-aware: the sleep
//! is clipped to the remaining budget and no attempt starts past it.
//! Used by `goma solve --remote ADDR` and the throughput bench's wire
//! leg.

use super::wire::{self, SolveSpec, WireReply};
use crate::solver::{SolveError, SolveResult};
use crate::util::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Per-socket-operation timeout when no overall deadline tightens it.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);

/// Retry policy. Defaults: 4 retries (5 attempts), 25 ms base doubling to
/// an 800 ms cap, jittered to `[backoff/2, backoff]`, no overall deadline.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Retries after the first attempt (0 = single-shot).
    pub max_retries: u32,
    /// First backoff window; doubles per retry up to `backoff_cap`.
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
    /// Overall wall-clock budget per [`WireClient::solve`] call, covering
    /// every attempt and backoff sleep. `None` = bounded by `max_retries`
    /// and the per-operation IO timeouts only.
    pub deadline: Option<Duration>,
    /// Jitter seed — fixed so a given client's retry schedule is
    /// reproducible (the chaos sweep depends on this).
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            max_retries: 4,
            backoff_base: Duration::from_millis(25),
            backoff_cap: Duration::from_millis(800),
            deadline: None,
            seed: 0xC11E57,
        }
    }
}

/// Why a [`WireClient::solve`] call did not return a result.
#[derive(Debug)]
pub enum ClientError {
    /// A definitive `422` solver-level answer (infeasible, interrupted).
    /// Not a transport failure — retrying cannot change it.
    Solve(SolveError),
    /// The server rejected the request itself (`400`/`404`/`405`) —
    /// deterministic, never retried.
    Rejected(String),
    /// A `200` reply began and then broke or failed to parse. Never
    /// retried (see the module docs); the caller decides what to do.
    TornReply(String),
    /// Every attempt failed retryably (or the deadline expired first);
    /// carries the last failure's description.
    Unavailable(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Solve(e) => write!(f, "solver error: {e}"),
            ClientError::Rejected(msg) => write!(f, "request rejected: {msg}"),
            ClientError::TornReply(msg) => {
                write!(f, "answer began but did not survive the socket: {msg}")
            }
            ClientError::Unavailable(msg) => write!(f, "server unavailable: {msg}"),
        }
    }
}

impl std::error::Error for ClientError {}

/// One attempt's verdict: final (return to the caller) or retryable.
enum Attempt {
    Done(Result<Box<SolveResult>, ClientError>),
    Retry(String),
}

/// A retrying `POST /solve` client. Holds no connection — each attempt
/// uses a fresh one (`Connection: close`), so a retry can never be
/// poisoned by a half-dead keep-alive socket.
pub struct WireClient {
    addr: String,
    opts: ClientOptions,
    rng: Rng,
    retries: u64,
}

impl WireClient {
    pub fn new<A: Into<String>>(addr: A) -> Self {
        WireClient::with_options(addr, ClientOptions::default())
    }

    pub fn with_options<A: Into<String>>(addr: A, opts: ClientOptions) -> Self {
        let rng = Rng::seed_from_u64(opts.seed);
        WireClient { addr: addr.into(), opts, rng, retries: 0 }
    }

    /// Attempts that failed retryably over this client's lifetime
    /// (provenance, like the service's `shard_retries` — a retry never
    /// changes an answer).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Solve `spec` remotely. `Ok` carries the server's bit-exact
    /// [`SolveResult`]; every shed / connect failure / pre-reply IO error
    /// is retried under the backoff policy, everything else is final.
    pub fn solve(&mut self, spec: &SolveSpec) -> Result<Box<SolveResult>, ClientError> {
        let body = spec.to_json().to_text();
        let deadline = self.opts.deadline.map(|d| Instant::now() + d);
        let mut backoff = self.opts.backoff_base;
        let mut last = String::new();
        for attempt in 0..=self.opts.max_retries {
            if attempt > 0 {
                // Jittered sleep in [backoff/2, backoff], clipped to the
                // remaining deadline; the window doubles per retry.
                let half = (backoff / 2).as_micros() as u64;
                let mut sleep = backoff / 2 + Duration::from_micros(self.rng.gen_range(half + 1));
                if let Some(d) = deadline {
                    let now = Instant::now();
                    if d <= now {
                        break;
                    }
                    sleep = sleep.min(d - now);
                }
                std::thread::sleep(sleep);
                backoff = (backoff * 2).min(self.opts.backoff_cap);
            }
            if deadline.is_some_and(|d| d <= Instant::now()) {
                break;
            }
            match self.attempt(&body, deadline) {
                Attempt::Done(r) => return r,
                Attempt::Retry(msg) => {
                    self.retries += 1;
                    last = msg;
                }
            }
        }
        if last.is_empty() {
            last = "deadline expired before the first attempt".to_string();
        }
        Err(ClientError::Unavailable(last))
    }

    /// One phased attempt (see the module docs for the phase → policy
    /// mapping).
    fn attempt(&self, body: &str, deadline: Option<Instant>) -> Attempt {
        let io_timeout = match deadline {
            Some(d) => {
                let now = Instant::now();
                if d <= now {
                    return Attempt::Retry("deadline expired".to_string());
                }
                (d - now).min(DEFAULT_IO_TIMEOUT)
            }
            None => DEFAULT_IO_TIMEOUT,
        };
        // Phase 1: connect. Refused/reset here means no server saw the
        // request at all.
        let mut stream = match TcpStream::connect(&self.addr) {
            Ok(s) => s,
            Err(e) => return Attempt::Retry(format!("connect: {e}")),
        };
        let _ = stream.set_read_timeout(Some(io_timeout));
        let _ = stream.set_write_timeout(Some(io_timeout));
        // Phase 2: send. A failed (even partial) send is unanswered by
        // construction — the server answers whole requests only.
        let req = format!(
            "POST /solve HTTP/1.1\r\nHost: goma\r\nConnection: close\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        if let Err(e) = stream.write_all(req.as_bytes()).and_then(|()| stream.flush()) {
            return Attempt::Retry(format!("send: {e}"));
        }
        // Phase 3: the status line — the commit point. Nothing readable
        // (EOF, reset, timeout, or a line too garbled to carry a status
        // code) means no answer was committed to us; retry.
        let mut reader = BufReader::new(stream);
        let mut status_line = String::new();
        match reader.read_line(&mut status_line) {
            Ok(0) => return Attempt::Retry("connection closed before a status line".to_string()),
            Ok(_) => {}
            Err(e) => return Attempt::Retry(format!("status line: {e}")),
        }
        let Some(status) = status_line.split_whitespace().nth(1).and_then(|s| s.parse::<u16>().ok())
        else {
            return Attempt::Retry(format!("garbled status line {status_line:?}"));
        };
        // Phase 4: headers + body. From here the policy splits on the
        // status: a 200's bytes are an answer in flight (failures are
        // final), everything else is still a refusal or verdict whose
        // loss is retryable.
        let mut content_length = 0usize;
        loop {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => {
                    return torn_or_retry(status, "connection closed mid-headers".to_string());
                }
                Ok(_) => {}
                Err(e) => return torn_or_retry(status, format!("headers: {e}")),
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.trim().parse().unwrap_or(0);
                }
            }
        }
        let mut raw = vec![0u8; content_length];
        if let Err(e) = reader.read_exact(&mut raw) {
            return torn_or_retry(status, format!("body: {e}"));
        }
        let Ok(reply_body) = String::from_utf8(raw) else {
            return torn_or_retry(status, "non-utf8 body".to_string());
        };
        classify(status, &reply_body)
    }
}

/// A post-status-line failure: final for a `200` (the answer began),
/// retryable for everything else (a lost refusal proves nothing).
fn torn_or_retry(status: u16, msg: String) -> Attempt {
    if status == 200 {
        Attempt::Done(Err(ClientError::TornReply(msg)))
    } else {
        Attempt::Retry(format!("HTTP {status}: {msg}"))
    }
}

/// Map a complete `(status, body)` reply onto the retry policy.
fn classify(status: u16, body: &str) -> Attempt {
    match status {
        200 => match wire::parse_reply(200, body) {
            Ok(WireReply::Ok(r)) => Attempt::Done(Ok(r)),
            // A complete-but-unparseable 200 (e.g. a corrupted reply) is
            // still an answer that began: final, never retried.
            Ok(_) => Attempt::Done(Err(ClientError::TornReply(
                "200 carried a non-ok payload".to_string(),
            ))),
            Err(e) => Attempt::Done(Err(ClientError::TornReply(e))),
        },
        422 => match wire::parse_reply(422, body) {
            Ok(WireReply::Solve(e)) => Attempt::Done(Err(ClientError::Solve(e))),
            // The verdict is deterministic; a garbled copy of it may be
            // re-requested safely.
            _ => Attempt::Retry("garbled 422 reply".to_string()),
        },
        503 | 429 => Attempt::Retry(format!("shed (HTTP {status})")),
        400 | 404 | 405 => {
            let detail = crate::util::Json::parse(body)
                .ok()
                .and_then(|v| v.get("error").and_then(|e| e.as_str().map(String::from)))
                .unwrap_or_else(|| body.trim().to_string());
            Attempt::Done(Err(ClientError::Rejected(format!("HTTP {status}: {detail}"))))
        }
        other => Attempt::Retry(format!("unexpected HTTP {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::TcpListener;

    #[test]
    fn classify_routes_every_status_family() {
        assert!(matches!(classify(503, "{\"status\":\"shed\"}"), Attempt::Retry(_)));
        assert!(matches!(classify(429, "{}"), Attempt::Retry(_)));
        assert!(matches!(
            classify(400, "{\"status\":\"bad_request\",\"error\":\"nope\"}"),
            Attempt::Done(Err(ClientError::Rejected(m))) if m.contains("nope")
        ));
        assert!(matches!(
            classify(200, "definitely not json"),
            Attempt::Done(Err(ClientError::TornReply(_)))
        ));
        assert!(matches!(
            classify(422, "{\"status\":\"error\",\"error\":\"no_feasible_mapping\"}"),
            Attempt::Done(Err(ClientError::Solve(SolveError::NoFeasibleMapping)))
        ));
        assert!(matches!(classify(418, ""), Attempt::Retry(_)));
    }

    #[test]
    fn torn_reply_is_final_only_for_200() {
        assert!(matches!(
            torn_or_retry(200, "body: eof".to_string()),
            Attempt::Done(Err(ClientError::TornReply(_)))
        ));
        assert!(matches!(torn_or_retry(503, "body: eof".to_string()), Attempt::Retry(_)));
    }

    #[test]
    fn connect_failures_retry_until_exhausted_with_counted_attempts() {
        // Bind-then-drop: the port was just free, so connecting fails fast.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = ClientOptions {
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(2),
            ..ClientOptions::default()
        };
        let mut client = WireClient::with_options(addr, opts);
        let spec = SolveSpec::new(
            crate::mapping::GemmShape::new(8, 8, 8),
            super::super::wire::ArchSpec::Template("eyeriss".into()),
        );
        let err = client.solve(&spec).unwrap_err();
        assert!(matches!(err, ClientError::Unavailable(_)), "{err}");
        assert_eq!(client.retries(), 3, "every failed attempt is counted");
    }

    #[test]
    fn a_torn_200_is_never_retried() {
        // A one-shot server that sends half a 200 and slams the socket.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accepts = std::sync::Arc::new(std::sync::atomic::AtomicU64::new(0));
        let seen = accepts.clone();
        let server = std::thread::spawn(move || {
            for stream in listener.incoming().take(1) {
                let mut s = stream.unwrap();
                seen.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                let mut buf = [0u8; 4096];
                use std::io::Read as _;
                let _ = s.read(&mut buf);
                let _ = s.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 999\r\n\r\n{\"st");
                // Drop: the client sees EOF mid-body.
            }
        });
        let mut client = WireClient::with_options(
            addr,
            ClientOptions { backoff_base: Duration::from_millis(1), ..ClientOptions::default() },
        );
        let spec = SolveSpec::new(
            crate::mapping::GemmShape::new(8, 8, 8),
            super::super::wire::ArchSpec::Template("eyeriss".into()),
        );
        let err = client.solve(&spec).unwrap_err();
        assert!(matches!(err, ClientError::TornReply(_)), "{err}");
        assert_eq!(client.retries(), 0, "a begun 200 must never be retried");
        server.join().unwrap();
        assert_eq!(accepts.load(std::sync::atomic::Ordering::SeqCst), 1);
    }
}
