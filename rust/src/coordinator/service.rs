//! The sharded mapping service: queueing, coalescing, a hash-sharded
//! result cache, an N-worker solve pool, and a persistent warm-start store.
//!
//! Thread-based (the offline registry has no async runtime). A dispatcher
//! thread owns the sharded state and drains the request queue in batch
//! windows; within a window requests group by **solve fingerprint** (the
//! in-flight/coalescing table), cached keys — positive *and* negative —
//! answer immediately, and the distinct uncached keys fan out to a
//! [`crate::util::parallel::ordered_map`] scoped pool of `workers` threads.
//! Coalescing holds by construction: a key is grouped within its window
//! and cached across windows, so at most one solve per in-flight key
//! happens no matter how many duplicate requests race in from different
//! client threads.
//!
//! **Thread-budget split.** The service's total solver concurrency is
//! `workers × solve_threads` ([`MappingService::with_solve_threads`]):
//! `workers` solves run concurrently across distinct keys, and each solve
//! fans its own search space over `solve_threads` engine threads
//! ([`crate::solver::solve_with_threads`]). When a window carries fewer
//! distinct keys than workers, the idle share of the budget is handed to
//! the keys actually in flight — a lone hot key gets the whole budget, up
//! to the engine's per-wave parallelism cap
//! ([`crate::solver::engine::WAVE_UNITS`] units in flight at once) —
//! which is safe because the engine's result is bit-identical for every
//! thread count, so the cache never observes the split.
//!
//! **Cross-solve candidate memoization** (DESIGN.md §8). All of a
//! window's solves draw their per-axis candidate lists from one
//! `Arc`-shared [`crate::solver::SharedCandidateStore`] keyed by the
//! accelerator's parameter fingerprint, so a batch of related shapes on
//! one arch builds each list once in total rather than once per solve —
//! invisible in results (store hits are bit-identical to local builds)
//! and measured by `coordinator_throughput`'s cold-vs-shared leg.
//!
//! The cache is hash-sharded by fingerprint (`fp % shards`, one shard per
//! worker) with per-shard hit metrics, byte-budgeted LRU eviction, and a
//! bloom-filter front per shard ([`super::cache`], DESIGN.md §12 —
//! `--cache-budget-bytes` / `GOMA_CACHE_BUDGET`; unbounded by default);
//! with a `--cache-dir`, the cache is seeded from the on-disk warm store
//! ([`super::warm`]) at spawn, and newly proved outcomes flush back
//! periodically (every [`MappingService::with_flush_every`] proofs or
//! [`MappingService::with_flush_interval`] of wall-clock — so a killed
//! process keeps all but the last window) and once more when the pool
//! exits, making repeated runs warm across processes. Handles are cheap
//! clones; the service exits when every handle is dropped, or
//! deterministically via [`ServiceHandle::shutdown`].
//!
//! **Cross-shape warm bounds** (DESIGN.md §6). With seeding on
//! ([`MappingService::with_seed_bounds`], `--seed-bounds`,
//! `GOMA_SEED_BOUNDS`; default on), each window's misses are grouped by
//! architecture ([`arch_options_fingerprint`]), ordered by shape
//! similarity, and fanned out in *waves* of `workers` keys. Every miss is
//! seeded with the tightest valid bound [`crate::solver::plan_seed`] can
//! extract from a per-arch **donor registry** of winning mappings — fed by
//! (a) earlier waves of the same batch and (b) warm-store entries for the
//! same arch under *other* fingerprints (which is why the store persists
//! each entry's arch fingerprint, [`super::warm::WarmEntry`]). A valid
//! bound leaves mapping and energy bit-identical and only shrinks search
//! effort, so seeding — like `solve_threads` — never enters the solve
//! fingerprint; certificate *effort counters* in cached entries record the
//! work the producing solve actually did under whatever bounds it had.

use super::cache::{BoundedShardCache, CacheEntry, CacheMetrics};
use super::warm::{WarmEntry, WarmOutcome, WarmStore};
use crate::arch::Accelerator;
use crate::mapping::{GemmShape, Mapping};
use crate::solver::{
    plan_seed, solve_dist, DistError, DistOptions, SeedBound, SharedCandidateStore, SolveError,
    SolveRequest, SolveResult, SolverOptions,
};
use crate::util::parallel::ordered_map;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fingerprint/on-disk format version. Mixed into every fingerprint and
/// into the warm-store header: bumping it cold-starts every cache. Also
/// the version the shard-protocol handshake pins (`solver::dist`): a
/// worker speaking another version is rejected at spawn, for the same
/// reason old files are rejected wholesale.
/// v5: the certificate gained the distributed-solve provenance counters
/// (`shards`/`shard_retries`, DESIGN.md §10) — v4 files are cold-started
/// wholesale, as every prior version was (v4 had added the bound-ordered
/// engine's unit-level counters, DESIGN.md §8).
/// v6: the certificate gained the supervision counters
/// (`shard_respawns`/`breaker_trips`, DESIGN.md §13), widening every
/// persisted line — v5 files are cold-started wholesale, like every
/// version before them.
pub const CACHE_FORMAT_VERSION: u32 = 6;

/// Donor mappings kept per architecture for seed planning. Bounds the
/// O(donors) re-cost work per miss; once full, the oldest entry is
/// replaced ring-buffer style (see [`DonorPool`]).
const MAX_DONORS_PER_ARCH: usize = 128;

/// Architectures the donor registry keeps pools for. The per-arch ring was
/// always capped, but the map of rings was not — a long-lived service fed
/// a stream of distinct architectures grew it forever. Past the cap the
/// least-recently-used arch pool is dropped (LRU over arch fingerprints,
/// [`DonorRegistry`]); losing a pool only loses seed *bounds*, never
/// answers — an unseeded re-solve is bit-identical (DESIGN.md §6).
const MAX_DONOR_ARCHES: usize = 64;

/// Crash-safe flush defaults (DESIGN.md §12): the dispatcher flushes the
/// warm store after this many newly proved outcomes, or when this much
/// time passes with proved outcomes still unflushed — so a SIGKILL loses
/// at most the last window, not the whole session.
const DEFAULT_FLUSH_EVERY: usize = 32;
const DEFAULT_FLUSH_INTERVAL: Duration = Duration::from_secs(5);

/// The shape-independent half of the solve key: a stable fingerprint of
/// the **full** architecture parameter set (capacities, PE count, node,
/// DRAM kind, ERT, bandwidths, residency preset — deliberately *not*
/// `arch.name`, which two different `Accelerator::custom` instances can
/// share), the solver options, and [`CACHE_FORMAT_VERSION`]. The seeding
/// planner groups donor mappings by this value: a mapping solved on one
/// shape is a seed candidate exactly for other shapes under the same
/// arch/options fingerprint.
pub fn arch_options_fingerprint(arch: &Accelerator, opts: SolverOptions) -> u64 {
    let mut h = crate::util::Fnv64::new();
    h.u32(CACHE_FORMAT_VERSION);
    // The architecture half is the accelerator's own parameter
    // fingerprint — the same value that keys the solver's cross-solve
    // candidate store, so "may share candidate lists" and "may share
    // donors/cache entries on this arch" are one notion of arch identity.
    h.u64(arch.param_fingerprint());
    h.u8(opts.exact_pe as u8);
    match opts.time_limit {
        None => h.u8(0),
        Some(d) => {
            h.u8(1);
            h.u64(d.as_nanos() as u64);
        }
    }
    // `opts.solve_threads`, `opts.seed_bounds`, `opts.simd`,
    // `opts.suffix_bounds`, and `opts.cache_budget_bytes` are deliberately
    // NOT hashed: the engine's result is bit-identical for every thread
    // count, a seeded solve's mapping/energy are bit-identical to the
    // unseeded one, the scan kernel and suffix bounds are pure latency
    // knobs with bit-identical answers and certificates, and a cache
    // budget only decides which proved outcomes stay resident — eviction
    // forces a deterministic re-solve, never a different answer (all
    // property-tested) — so services with different thread budgets,
    // seeding switches, kernel configurations, or memory budgets must
    // share cache entries; hashing any of these knobs would split the
    // warm store by deployment configuration.
    h.finish()
}

/// The cache/coalescing/persistence key: [`arch_options_fingerprint`] with
/// the GEMM shape folded in.
pub fn solve_fingerprint(shape: GemmShape, arch: &Accelerator, opts: SolverOptions) -> u64 {
    shape_fingerprint(arch_options_fingerprint(arch, opts), shape)
}

/// Fold a GEMM shape into an arch/options fingerprint — the second half of
/// [`solve_fingerprint`], split out so the request path (which carries the
/// arch half for donor grouping) derives the key without rehashing the
/// whole architecture.
pub fn shape_fingerprint(arch_fp: u64, shape: GemmShape) -> u64 {
    let mut h = crate::util::Fnv64::seeded(arch_fp);
    h.u64(shape.x);
    h.u64(shape.y);
    h.u64(shape.z);
    h.finish()
}

struct Request {
    fp: u64,
    /// [`arch_options_fingerprint`] — the donor-registry grouping key.
    arch_fp: u64,
    shape: GemmShape,
    arch: Accelerator,
    /// Per-request wall-clock deadline ([`ServiceHandle::submit_with_deadline`]):
    /// the instant by which the *answer* is due. Mapped onto the engine's
    /// `time_limit` at solve start — so queueing time already spent counts
    /// against it — and deliberately NEVER part of the solve fingerprint:
    /// a deadline shapes when a solve may be cut short, not what the key's
    /// proved answer is (DESIGN.md §9).
    deadline: Option<Instant>,
    reply: Sender<WarmOutcome>,
}

enum Msg {
    /// Boxed: an `Accelerator` clone travels with every request, and the
    /// variant should not bloat the queue's unit size.
    Solve(Box<Request>),
    /// Cooperative termination marker (see [`ServiceHandle::shutdown`]).
    Shutdown,
}

/// Service counters (exposed for the CLI's `serve` output, the throughput
/// bench, and the concurrency property suite).
///
/// Accounting: `requests` counts submissions *accepted* by a live
/// dispatcher (a submission that can only resolve to `ServiceUnavailable`
/// is un-counted), and every accepted request lands in exactly one of
/// `cache_hits`, `coalesced` (duplicate of an in-flight key beyond the
/// first), `solves` (it triggered a successful solve), or `errors` (it
/// triggered a solve that reported infeasibility) — so once the service is
/// quiescent, `requests == cache_hits + coalesced + solves + errors`.
/// `warm_hits` and `negative_hits` are overlays counting the subset of
/// `cache_hits` served from the on-disk store / from a cached
/// infeasibility; they do not enter the sum. The seeding counters are
/// overlays too: `seeded_solves` counts the subset of `solves + errors`
/// whose search was launched with a warm bound (so
/// `seeded_solves ≤ solves + errors` once quiescent), and
/// `seed_accepted`/`seed_rejected` tally donor re-costs during planning
/// (every seeded solve required ≥ 1 accepted donor, so
/// `seed_accepted ≥ seeded_solves`). None of the three enter the sum.
///
/// One narrow caveat: a submission racing the pool's final teardown
/// instants (after the dispatcher's exit drain, before its receiver
/// drops) is accepted by the channel but never answered or reconciled, so
/// it can leave `requests`/`queue_depth` one high. The invariant is exact
/// whenever quiescence is observed through answered requests on a live
/// service — which is how the property suite asserts it.
#[derive(Debug)]
pub struct ServiceMetrics {
    requests: AtomicU64,
    solves: AtomicU64,
    cache_hits: AtomicU64,
    coalesced: AtomicU64,
    errors: AtomicU64,
    warm_hits: AtomicU64,
    negative_hits: AtomicU64,
    seeded_solves: AtomicU64,
    seed_accepted: AtomicU64,
    seed_rejected: AtomicU64,
    shard_solves: AtomicU64,
    shard_retries: AtomicU64,
    shard_respawns: AtomicU64,
    breaker_trips: AtomicU64,
    /// Latched while the most recent distributed solve reported a tripped
    /// spawn breaker (DESIGN.md §13); cleared by the next breaker-free
    /// distributed solve. Feeds `/readyz`'s `degraded` state.
    breaker_open: AtomicBool,
    /// Warm-store flush attempts that failed (ENOSPC, torn write, …).
    /// Answers are unaffected — proofs stay cached in RAM and every later
    /// flush window retries the full union (DESIGN.md §13).
    warm_write_failures: AtomicU64,
    /// Latched while warm-store flushes are failing (RAM-only degraded
    /// mode); cleared by the first flush that lands. Feeds `/readyz`.
    warm_degraded: AtomicBool,
    queue_depth: AtomicU64,
    per_shard_hits: Vec<AtomicU64>,
    /// Cache-tier counters (evictions, resident bytes, bloom fast
    /// misses/false positives) — owned here, written by the
    /// [`super::cache::BoundedShardCache`] that holds a clone.
    cache: Arc<CacheMetrics>,
}

impl ServiceMetrics {
    fn new(shards: usize) -> Self {
        ServiceMetrics {
            requests: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            negative_hits: AtomicU64::new(0),
            seeded_solves: AtomicU64::new(0),
            seed_accepted: AtomicU64::new(0),
            seed_rejected: AtomicU64::new(0),
            shard_solves: AtomicU64::new(0),
            shard_retries: AtomicU64::new(0),
            shard_respawns: AtomicU64::new(0),
            breaker_trips: AtomicU64::new(0),
            breaker_open: AtomicBool::new(false),
            warm_write_failures: AtomicU64::new(0),
            warm_degraded: AtomicBool::new(false),
            queue_depth: AtomicU64::new(0),
            per_shard_hits: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            cache: Arc::new(CacheMetrics::default()),
        }
    }

    /// `(requests, solves, cache_hits, coalesced, errors)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.solves.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.coalesced.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
        )
    }

    /// Cache hits answered by entries loaded from the persistent store.
    pub fn warm_hits(&self) -> u64 {
        self.warm_hits.load(Ordering::Relaxed)
    }

    /// Cache hits answered by a cached infeasibility (negative cache).
    pub fn negative_hits(&self) -> u64 {
        self.negative_hits.load(Ordering::Relaxed)
    }

    /// Solves launched with a cross-shape warm bound (overlay on
    /// `solves + errors`).
    pub fn seeded_solves(&self) -> u64 {
        self.seeded_solves.load(Ordering::Relaxed)
    }

    /// Donor re-costs accepted during seed planning (the donor was
    /// feasible on the target shape, so its bound was valid).
    pub fn seed_accepted(&self) -> u64 {
        self.seed_accepted.load(Ordering::Relaxed)
    }

    /// Donor re-costs rejected by the target-feasibility check.
    pub fn seed_rejected(&self) -> u64 {
        self.seed_rejected.load(Ordering::Relaxed)
    }

    /// Solves answered by the distributed coordinator
    /// ([`crate::solver::solve_dist`], DESIGN.md §10) — an overlay on
    /// `solves`, like `seeded_solves`: it records *how* those solves ran
    /// (fanned over worker processes), never enters the accounting sum,
    /// and the results are bit-identical to in-process solves.
    pub fn shard_solves(&self) -> u64 {
        self.shard_solves.load(Ordering::Relaxed)
    }

    /// Total shard unit ranges re-queued after a worker died, hung, or
    /// corrupted its stream, summed over all distributed solves
    /// (provenance only — a retry never changes an answer).
    pub fn shard_retries(&self) -> u64 {
        self.shard_retries.load(Ordering::Relaxed)
    }

    /// Workers respawned into dead shard slots, summed over all
    /// distributed solves (DESIGN.md §13; provenance only — a respawned
    /// worker re-scans pure data, never changing an answer).
    pub fn shard_respawns(&self) -> u64 {
        self.shard_respawns.load(Ordering::Relaxed)
    }

    /// Spawn circuit-breaker trips summed over all distributed solves
    /// (the breaker latches per solve, so each solve contributes 0 or 1).
    /// A tripped solve is finished by the in-process sweep — answers are
    /// bit-identical either way.
    pub fn breaker_trips(&self) -> u64 {
        self.breaker_trips.load(Ordering::Relaxed)
    }

    /// Whether the most recent distributed solve tripped its spawn
    /// breaker (cleared by the next breaker-free distributed solve).
    /// Feeds `/readyz`'s `degraded` state.
    pub fn breaker_open(&self) -> bool {
        self.breaker_open.load(Ordering::Relaxed)
    }

    /// Warm-store flush attempts that failed (the disk tier is unhealthy;
    /// the RAM tier keeps every proof and later windows retry the union).
    pub fn warm_write_failures(&self) -> u64 {
        self.warm_write_failures.load(Ordering::Relaxed)
    }

    /// Whether the service is in RAM-only degraded mode: warm-store
    /// flushes are failing, answers keep flowing, nothing new persists
    /// until a flush lands again (DESIGN.md §13). Feeds `/readyz`.
    pub fn warm_degraded(&self) -> bool {
        self.warm_degraded.load(Ordering::Relaxed)
    }

    /// Requests submitted but not yet answered (gauge; 0 when quiescent).
    pub fn queue_depth(&self) -> u64 {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Per-shard cache-hit counts, indexed by shard id.
    pub fn per_shard_hits(&self) -> Vec<u64> {
        self.per_shard_hits
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect()
    }

    /// Cache entries evicted under the byte budget (DESIGN.md §12).
    /// Eviction moves hit rates only — answers are bit-identical to an
    /// unbounded run (property-tested).
    pub fn cache_evictions(&self) -> u64 {
        self.cache.evictions()
    }

    /// Accounted bytes resident in the sharded result cache (gauge).
    pub fn cache_bytes(&self) -> u64 {
        self.cache.bytes()
    }

    /// Cold misses answered by the bloom front without taking a shard
    /// lock ("definitely absent").
    pub fn bloom_hits(&self) -> u64 {
        self.cache.bloom_hits()
    }

    /// Bloom "maybe present" probes that found nothing in the shard —
    /// the only counter eviction is allowed to inflate beyond hit-rate
    /// shifts (evicted keys stay set until a filter rebuild).
    pub fn bloom_false_positives(&self) -> u64 {
        self.cache.bloom_false_positives()
    }
}

/// A pending reply that can be waited on (futures-lite, std-only).
pub struct Pending {
    rx: Receiver<WarmOutcome>,
}

impl Pending {
    /// Block until the mapping is solved (or fails). A reply channel that
    /// closes without an answer means the worker pool is gone — that is
    /// [`SolveError::ServiceUnavailable`], *not* infeasibility.
    pub fn wait(self) -> Result<Arc<SolveResult>, SolveError> {
        self.rx.recv().unwrap_or(Err(SolveError::ServiceUnavailable))
    }
}

/// Client handle: cheap to clone, submits mapping requests.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Msg>,
    options: SolverOptions,
    metrics: Arc<ServiceMetrics>,
    joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl ServiceHandle {
    /// Submit a request; returns a [`Pending`] so callers can batch many
    /// submissions before waiting (in-flight duplicates coalesce).
    pub fn submit(&self, shape: GemmShape, arch: Accelerator) -> Pending {
        self.submit_with_deadline(shape, arch, None)
    }

    /// [`ServiceHandle::submit`] with a per-request answer deadline (the
    /// wire path's entry point). At solve start the engine's wall-clock
    /// budget becomes the *remaining* time to the deadline (capped by the
    /// service-wide `time_limit`), so queueing time already spent counts
    /// against the request; a request whose deadline expires while still
    /// queued is answered [`SolveError::Interrupted`] without burning a
    /// solve. Coalesced waiters on one key relax to the most generous
    /// deadline among them (no deadline wins outright) — a tighter waiter
    /// can never cut short an answer another waiter is owed. Deadlines
    /// never enter the solve fingerprint, and no deadline-capped outcome
    /// is ever cached unless it is a proof (DESIGN.md §9).
    pub fn submit_with_deadline(
        &self,
        shape: GemmShape,
        arch: Accelerator,
        deadline: Option<Instant>,
    ) -> Pending {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let arch_fp = arch_options_fingerprint(&arch, self.options);
        let fp = shape_fingerprint(arch_fp, shape);
        let (reply, rx) = channel();
        let msg = Msg::Solve(Box::new(Request { fp, arch_fp, shape, arch, deadline, reply }));
        if self.tx.send(msg).is_err() {
            // Dispatcher gone: the reply sender travelled inside the failed
            // message and was dropped with it, so `wait` sees a closed
            // channel and reports ServiceUnavailable. The submission was
            // never accepted, so it is un-counted entirely — `requests`
            // tracks accepted submissions and the accounting invariant
            // stays exact.
            self.metrics.requests.fetch_sub(1, Ordering::Relaxed);
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        }
        Pending { rx }
    }

    /// Convenience: submit and wait.
    pub fn map(&self, shape: GemmShape, arch: Accelerator) -> Result<Arc<SolveResult>, SolveError> {
        self.submit(shape, arch).wait()
    }

    /// Batch submission against one architecture: returns the pendings in
    /// input order. Duplicate shapes coalesce into a single solve, so a
    /// whole workload can be submitted in one call.
    pub fn submit_batch(&self, arch: &Accelerator, shapes: &[GemmShape]) -> Vec<Pending> {
        shapes.iter().map(|&s| self.submit(s, arch.clone())).collect()
    }

    /// Map every GEMM of `workload` on `arch` in one call; results are in
    /// `workload.gemms` order. The service solves each *distinct* shape
    /// once (duplicated GEMM shapes inside a workload coalesce).
    pub fn map_workload(
        &self,
        workload: &crate::workloads::Workload,
        arch: &Accelerator,
    ) -> Vec<Result<Arc<SolveResult>, SolveError>> {
        let shapes: Vec<GemmShape> = workload.gemms.iter().map(|g| g.shape).collect();
        self.submit_batch(arch, &shapes)
            .into_iter()
            .map(|p| p.wait())
            .collect()
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Terminate the worker pool deterministically: the dispatcher finishes
    /// its current batch window, merges every cache shard into the warm
    /// store, and (with a cache dir configured) flushes it to disk. Blocks
    /// until the pool has exited, so a subsequent cold process sees the
    /// complete store. Requests queued behind the shutdown marker — and any
    /// submitted through surviving clones of this handle afterwards —
    /// resolve to [`SolveError::ServiceUnavailable`].
    ///
    /// Dropping every handle instead also stops the pool and flushes, but
    /// asynchronously — a process may exit before that flush lands; call
    /// `shutdown` when the warm store matters.
    pub fn shutdown(self) {
        let _ = self.tx.send(Msg::Shutdown);
        let handles: Vec<JoinHandle<()>> = {
            let mut g = self.joins.lock().unwrap();
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

/// The mapping service configuration: solver options, worker-pool size
/// (== cache shard count), the optional persistent cache location, and
/// the optional distributed-solve fan-out. Note the two unrelated
/// "shard" axes: `workers` shards the *cache* across the in-process
/// pool, while `solve_shards` fans each individual miss across worker
/// *processes* ([`crate::solver::solve_dist`], DESIGN.md §10).
pub struct MappingService {
    options: SolverOptions,
    workers: usize,
    cache_dir: Option<PathBuf>,
    solve_shards: usize,
    shard_bin: Option<PathBuf>,
    flush_every: usize,
    flush_interval: Duration,
    donor_arch_cap: usize,
}

impl Default for MappingService {
    fn default() -> Self {
        MappingService {
            options: SolverOptions::default(),
            workers: 1,
            cache_dir: None,
            solve_shards: 1,
            shard_bin: None,
            flush_every: DEFAULT_FLUSH_EVERY,
            flush_interval: DEFAULT_FLUSH_INTERVAL,
            donor_arch_cap: MAX_DONOR_ARCHES,
        }
    }
}

impl MappingService {
    pub fn new(options: SolverOptions) -> Self {
        MappingService {
            options,
            ..MappingService::default()
        }
    }

    /// Size of the solve pool and of the sharded cache (min 1). `1`
    /// degenerates to the serial service every parallel run is checked
    /// against.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Intra-solve engine threads per pooled solve (the other factor of
    /// the `workers × solve_threads` budget split — see the module docs).
    /// `0` restores the auto default (`GOMA_SOLVE_THREADS`, else serial).
    /// Results are bit-identical for every value, so this knob never
    /// enters the solve fingerprint.
    pub fn with_solve_threads(mut self, solve_threads: usize) -> Self {
        self.options.solve_threads = solve_threads;
        self
    }

    /// Switch cross-shape warm bounds on or off for batch misses (see the
    /// module docs). Mappings and energies are bit-identical either way —
    /// seeding only shrinks search effort — so, like `solve_threads`, the
    /// knob never enters the solve fingerprint. The unset default resolves
    /// through `GOMA_SEED_BOUNDS`, else on.
    pub fn with_seed_bounds(mut self, on: bool) -> Self {
        self.options.seed_bounds = Some(on);
        self
    }

    /// Force the SIMD scan kernel on or off (`None` default resolves via
    /// `GOMA_SIMD`, then runtime CPU detection). Answers and certificates
    /// are bit-identical for every value (DESIGN.md §11), so — like
    /// `solve_threads` — the knob never enters the solve fingerprint.
    pub fn with_simd(mut self, on: bool) -> Self {
        self.options.simd = Some(on);
        self
    }

    /// Switch the capacity-aware suffix bounds on or off (`None` default
    /// resolves via `GOMA_SUFFIX_BOUNDS`, else on). The answer is
    /// bit-identical either way and node counts can only shrink with the
    /// bounds on (DESIGN.md §11), so the knob never enters the solve
    /// fingerprint.
    pub fn with_suffix_bounds(mut self, on: bool) -> Self {
        self.options.suffix_bounds = Some(on);
        self
    }

    /// Enable the persistent warm-start cache rooted at `dir` (see
    /// [`super::warm`] for the format and invalidation rules).
    pub fn with_cache_dir<P: Into<PathBuf>>(mut self, dir: P) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Fan each cache miss across `n` distributed worker processes
    /// ([`crate::solver::solve_dist`], DESIGN.md §10). `1` (the default)
    /// keeps every solve in-process. Answers are bit-identical either
    /// way, so — like `solve_threads` and `seed_bounds` — the knob never
    /// enters the solve fingerprint; the `shard_solves`/`shard_retries`
    /// metrics record which route ran.
    pub fn with_shards(mut self, n: usize) -> Self {
        self.solve_shards = n.max(1);
        self
    }

    /// Explicit worker binary for distributed solves. Unset resolves
    /// through `GOMA_SHARD_BIN`, else the current executable.
    pub fn with_shard_bin<P: Into<PathBuf>>(mut self, bin: P) -> Self {
        self.shard_bin = Some(bin.into());
        self
    }

    /// Byte budget for the sharded result cache and the warm store's
    /// on-disk cap (DESIGN.md §12). Eviction under the budget only moves
    /// hit rates — answers are bit-identical for every value
    /// (property-tested) — so, like `solve_threads`, the knob never
    /// enters the solve fingerprint. The unset default resolves through
    /// `GOMA_CACHE_BUDGET`, else unbounded.
    pub fn with_cache_budget(mut self, bytes: u64) -> Self {
        self.options.cache_budget_bytes = Some(bytes);
        self
    }

    /// Flush the warm store after every `n` newly proved outcomes (min 1;
    /// the crash-safe flush threshold — see [`service_loop`]).
    pub fn with_flush_every(mut self, n: usize) -> Self {
        self.flush_every = n.max(1);
        self
    }

    /// Flush the warm store when proved outcomes have sat unflushed for
    /// this long (the crash-safe flush period).
    pub fn with_flush_interval(mut self, interval: Duration) -> Self {
        self.flush_interval = interval;
        self
    }

    /// Cap on distinct architectures the donor registry keeps pools for
    /// (default [`MAX_DONOR_ARCHES`]; min 1). Exposed for the bounding
    /// tests — dropping a pool loses seed bounds, never answers.
    pub fn with_donor_arch_cap(mut self, n: usize) -> Self {
        self.donor_arch_cap = n.max(1);
        self
    }

    /// Spawn the dispatcher; returns the client handle. The pool exits when
    /// every handle is dropped or [`ServiceHandle::shutdown`] is called.
    pub fn spawn(self) -> ServiceHandle {
        let workers = self.workers.max(1);
        let metrics = Arc::new(ServiceMetrics::new(workers));
        let options = self.options;
        let budget = options.resolved_cache_budget();
        let store = Arc::new(WarmStore::open(self.cache_dir, budget));
        // Seed the cache from the warm store in fingerprint order (fp
        // routing keeps the partition stable for a given worker count;
        // the sort makes LRU ticks — and therefore which loaded entries a
        // tiny budget retains — deterministic for a given store).
        let cache = BoundedShardCache::new(workers, budget, metrics.cache.clone());
        let mut seed: Vec<(u64, WarmEntry)> = store.loaded().collect();
        seed.sort_by_key(|&(fp, _)| fp);
        for (fp, e) in seed {
            cache.insert(fp, CacheEntry { result: e.outcome, arch_fp: e.arch_fp, warm: true });
        }
        let (tx, rx) = channel::<Msg>();
        let m = metrics.clone();
        let cfg = ServiceConfig {
            workers,
            options,
            dist: (self.solve_shards >= 2).then(|| DistOptions {
                shards: self.solve_shards,
                worker_bin: self.shard_bin,
                ..DistOptions::default()
            }),
            flush_every: self.flush_every.max(1),
            flush_interval: self.flush_interval,
            donor_arch_cap: self.donor_arch_cap.max(1),
        };
        let join = std::thread::spawn(move || {
            service_loop(rx, cache, m, store, cfg);
        });
        ServiceHandle {
            tx,
            options,
            metrics,
            joins: Arc::new(Mutex::new(vec![join])),
        }
    }
}

/// Everything the dispatcher needs beyond its channels and stores, bundled
/// so [`service_loop`]'s signature stays readable.
struct ServiceConfig {
    workers: usize,
    options: SolverOptions,
    dist: Option<DistOptions>,
    flush_every: usize,
    flush_interval: Duration,
    donor_arch_cap: usize,
}

/// One architecture's seed-donor pool: a deduplicated ring of the most
/// recent [`MAX_DONORS_PER_ARCH`] winning mappings. A ring (not
/// insert-only) on purpose — on long-lived services and large batches the
/// freshest winners are the most shape-similar donors for the very next
/// wave, so once full the oldest entry is replaced rather than the newest
/// dropped. Deterministic for a given insertion order.
#[derive(Default)]
struct DonorPool {
    items: Vec<Mapping>,
    /// Next replacement slot once the ring is full.
    cursor: usize,
}

impl DonorPool {
    fn insert(&mut self, mapping: Mapping) {
        if self.items.contains(&mapping) {
            return;
        }
        if self.items.len() < MAX_DONORS_PER_ARCH {
            self.items.push(mapping);
        } else {
            self.items[self.cursor] = mapping;
            self.cursor = (self.cursor + 1) % MAX_DONORS_PER_ARCH;
        }
    }
}

/// The donor registry: per-arch [`DonorPool`]s behind an LRU bound on the
/// number of *architectures* (the per-arch rings were always capped, but
/// the map of rings used to grow without bound — this is the fix).
/// Recency is a `BTreeMap<tick, arch_fp>` over monotonic unique ticks, so
/// which pool an over-cap insert drops is a pure function of the
/// insert/lookup sequence — never of hash iteration order. Both inserts
/// and donor lookups promote the arch: the architectures actively being
/// solved keep their pools. Dropping a pool only costs future seed
/// *bounds*; an unseeded re-solve is bit-identical (DESIGN.md §6).
struct DonorRegistry {
    pools: HashMap<u64, (DonorPool, u64)>,
    recency: BTreeMap<u64, u64>,
    next_tick: u64,
    cap: usize,
}

impl DonorRegistry {
    fn new(cap: usize) -> Self {
        DonorRegistry {
            pools: HashMap::new(),
            recency: BTreeMap::new(),
            next_tick: 0,
            cap: cap.max(1),
        }
    }

    fn promote(&mut self, arch_fp: u64) {
        let next = self.next_tick;
        if let Some((_, tick)) = self.pools.get_mut(&arch_fp) {
            self.recency.remove(tick);
            *tick = next;
            self.recency.insert(next, arch_fp);
            self.next_tick = next + 1;
        }
    }

    /// Record `mapping` as a seed donor for its architecture, evicting the
    /// least-recently-used arch pool if a new pool would exceed the cap.
    fn insert(&mut self, arch_fp: u64, mapping: Mapping) {
        if let Some((pool, _)) = self.pools.get_mut(&arch_fp) {
            pool.insert(mapping);
        } else {
            while self.pools.len() >= self.cap {
                let (&tick, &victim) = self.recency.iter().next().expect("cap >= 1");
                self.recency.remove(&tick);
                self.pools.remove(&victim);
            }
            let mut pool = DonorPool::default();
            pool.insert(mapping);
            let tick = self.next_tick;
            self.next_tick = tick + 1;
            self.pools.insert(arch_fp, (pool, tick));
            self.recency.insert(tick, arch_fp);
            return;
        }
        self.promote(arch_fp);
    }

    /// The donor mappings for an architecture (empty when no pool is
    /// retained), promoting the pool to most-recently-used.
    fn donors(&mut self, arch_fp: u64) -> &[Mapping] {
        self.promote(arch_fp);
        self.pools
            .get(&arch_fp)
            .map(|(p, _)| p.items.as_slice())
            .unwrap_or(&[])
    }

    /// Distinct architectures currently retained (bounded by `cap`).
    fn arches(&self) -> usize {
        self.pools.len()
    }
}

/// Map a per-request deadline onto the engine's wall-clock budget at solve
/// start: the budget is the *remaining* time to the deadline (so queueing
/// time already spent counts against the request), capped by the
/// service-wide `time_limit`. `None` means the deadline has already
/// passed — the solve must not start at all.
fn effective_options(options: SolverOptions, deadline: Option<Instant>) -> Option<SolverOptions> {
    let Some(d) = deadline else {
        return Some(options);
    };
    let now = Instant::now();
    if d <= now {
        return None;
    }
    let remaining = d - now;
    let limit = match options.time_limit {
        Some(l) => l.min(remaining),
        None => remaining,
    };
    Some(SolverOptions { time_limit: Some(limit), ..options })
}

fn reply_all(waiters: Vec<Request>, result: &WarmOutcome, m: &ServiceMetrics) {
    for w in waiters {
        // Decrement BEFORE the send: the reply channel is the happens-before
        // edge to the waiter, so a client that observed its answer must
        // already see this request gone from the gauge.
        m.queue_depth.fetch_sub(1, Ordering::Relaxed);
        let _ = w.reply.send(result.clone());
    }
}

/// Land a flush window, tracking disk-tier health (DESIGN.md §13). The
/// store merges the window into its RAM view *before* touching the file,
/// so a failed write loses nothing: the failure is counted, the degraded
/// latch set (logged once), and — every flush being its own recovery
/// probe — the next window rewrites the full union. The first flush that
/// lands clears the latch.
fn flush_window(store: &WarmStore, pending: &mut Vec<(u64, WarmEntry)>, m: &ServiceMetrics) {
    match store.merge_and_flush(pending.drain(..)) {
        Ok(()) => {
            if m.warm_degraded.swap(false, Ordering::Relaxed) {
                eprintln!("goma: warm-store flush recovered; disk tier healthy again");
            }
        }
        Err(e) => {
            m.warm_write_failures.fetch_add(1, Ordering::Relaxed);
            if !m.warm_degraded.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "goma: warm-store flush failed ({e}); entering RAM-only degraded mode \
                     (answers keep flowing, proofs stay cached in RAM, and each flush \
                     window retries the full union)"
                );
            }
        }
    }
}

fn service_loop(
    rx: Receiver<Msg>,
    cache: BoundedShardCache,
    m: Arc<ServiceMetrics>,
    store: Arc<WarmStore>,
    cfg: ServiceConfig,
) {
    let ServiceConfig { workers, options, dist, flush_every, flush_interval, donor_arch_cap } =
        cfg;
    let seed_on = options.resolved_seed_bounds();
    // The cross-solve candidate store (DESIGN.md §8): per-axis candidate
    // lists depend only on the architecture's parameters, so one
    // `Arc`-shared store lets every pooled solve — across waves, batch
    // windows, and worker threads — fetch each list instead of rebuilding
    // it. Store hits are bit-identical to local builds, so the cache and
    // warm store never observe the sharing.
    let candidates = Arc::new(SharedCandidateStore::new());
    // The donor registry: per arch/options fingerprint, winning mappings
    // usable as cross-shape warm bounds. Seeded from the warm store (other
    // fingerprints, same arch — the cross-process donor path) and fed by
    // every proved solve from then on. The harvest is sorted by
    // fingerprint before insertion: store iteration order is SipHash-
    // dependent, and an unsorted walk would make which entries survive
    // the pool caps vary between identical runs.
    let mut donors = DonorRegistry::new(donor_arch_cap);
    if seed_on {
        let mut harvest: Vec<(u64, u64, Mapping)> = store
            .loaded()
            .filter_map(|(fp, e)| e.outcome.ok().map(|r| (e.arch_fp, fp, r.mapping)))
            .collect();
        harvest.sort_by_key(|&(afp, fp, _)| (afp, fp));
        for (afp, _, mapping) in harvest {
            donors.insert(afp, mapping);
        }
    }
    // The crash-safe flush window (DESIGN.md §12): newly proved outcomes
    // accumulate here and merge into the warm store every `flush_every`
    // proofs or `flush_interval` of wall-clock, so a killed process keeps
    // all but the last window. The store's merged view already carries
    // everything previously flushed or loaded — each flush hands over
    // only the new window.
    let mut pending: Vec<(u64, WarmEntry)> = Vec::new();
    let mut last_flush = Instant::now();
    let mut quit = false;
    while !quit {
        let first = match rx.recv_timeout(flush_interval) {
            Ok(Msg::Solve(r)) => *r,
            Ok(Msg::Shutdown) => break,
            Err(RecvTimeoutError::Timeout) => {
                // Idle period: land whatever window accumulated — and, in
                // degraded mode, probe for recovery even when the window
                // is empty (the store's merged view still carries the
                // proofs earlier failed flushes could not land).
                if !pending.is_empty() || m.warm_degraded.load(Ordering::Relaxed) {
                    flush_window(&store, &mut pending, &m);
                    last_flush = Instant::now();
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        // Batch window: drain whatever queued behind the first request.
        let mut batch = vec![first];
        while let Ok(msg) = rx.try_recv() {
            match msg {
                Msg::Solve(r) => batch.push(*r),
                Msg::Shutdown => {
                    quit = true;
                    break;
                }
            }
        }
        // The window's in-flight/coalescing table: group by fingerprint in
        // arrival order, so each distinct key solves at most once no matter
        // how many duplicates raced in.
        let mut groups: Vec<(u64, Vec<Request>)> = Vec::new();
        let mut index: HashMap<u64, usize> = HashMap::new();
        for r in batch {
            match index.get(&r.fp) {
                Some(&i) => groups[i].1.push(r),
                None => {
                    index.insert(r.fp, groups.len());
                    groups.push((r.fp, vec![r]));
                }
            }
        }
        // Split cached keys (positive or negative) from misses, and answer
        // the hits before starting any (possibly slow) solve.
        let mut misses: Vec<(u64, u64, Vec<Request>)> = Vec::new();
        for (fp, waiters) in groups {
            if waiters.len() > 1 {
                m.coalesced.fetch_add(waiters.len() as u64 - 1, Ordering::Relaxed);
            }
            match cache.get(fp) {
                Some(e) => {
                    m.cache_hits.fetch_add(1, Ordering::Relaxed);
                    m.per_shard_hits[cache.shard_of(fp)].fetch_add(1, Ordering::Relaxed);
                    if e.warm {
                        m.warm_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    if e.result.is_err() {
                        m.negative_hits.fetch_add(1, Ordering::Relaxed);
                    }
                    reply_all(waiters, &e.result, &m);
                }
                None => {
                    let afp = waiters[0].arch_fp;
                    misses.push((fp, afp, waiters));
                }
            }
        }
        // Fan the distinct misses out to the scoped solve pool, answering
        // each key's waiters the moment its *own* solve finishes. With
        // seeding on, the misses are grouped by arch and ordered by shape
        // similarity, then chunked into waves of `workers` keys: each
        // wave's winners enter the donor registry before the next wave
        // plans its bounds, so a batch of related shapes tightens itself
        // as it drains (the wave barrier is the price of fresher donors;
        // with seeding off the whole window is one wave, the pre-seeding
        // behavior). Each pooled solve builds its own Arc-held SearchSpace
        // on its worker thread, and the waiters hand over through per-key
        // Mutex slots so only `Send` data crosses threads (the reply
        // senders never need to be `Sync`).
        if seed_on {
            misses.sort_by_key(|(_, afp, w)| (*afp, crate::solver::similarity_key(w[0].shape)));
        }
        let wave_size = if seed_on {
            workers.max(1)
        } else {
            misses.len().max(1)
        };
        for wave in misses.chunks_mut(wave_size) {
            let mut keys: Vec<(u64, u64, bool)> = Vec::with_capacity(wave.len());
            let mut inputs: Vec<(GemmShape, Accelerator, Option<SeedBound>, Option<Instant>)> =
                Vec::with_capacity(wave.len());
            let mut slots: Vec<Mutex<Vec<Request>>> = Vec::with_capacity(wave.len());
            for (fp, afp, waiters) in wave.iter_mut() {
                let shape = waiters[0].shape;
                let arch = waiters[0].arch.clone();
                // Coalesced waiters relax to the most generous deadline:
                // one waiter with no deadline means the solve runs
                // uncapped (a tighter co-waiter must never cut short an
                // answer another waiter is owed), otherwise the latest
                // deadline wins.
                let mut deadline = waiters[0].deadline;
                for w in waiters.iter().skip(1) {
                    deadline = match (deadline, w.deadline) {
                        (Some(a), Some(b)) => Some(a.max(b)),
                        _ => None,
                    };
                }
                let seed = if seed_on {
                    let pool = donors.donors(*afp);
                    let plan = plan_seed(pool, shape, &arch, options.exact_pe);
                    m.seed_accepted.fetch_add(plan.accepted, Ordering::Relaxed);
                    m.seed_rejected.fetch_add(plan.rejected, Ordering::Relaxed);
                    if plan.bound.is_some() {
                        m.seeded_solves.fetch_add(1, Ordering::Relaxed);
                    }
                    plan.bound
                } else {
                    None
                };
                keys.push((*fp, *afp, deadline.is_some()));
                inputs.push((shape, arch, seed, deadline));
                slots.push(Mutex::new(std::mem::take(waiters)));
            }
            // The workers × solve_threads budget split: a wave with fewer
            // distinct keys than workers spreads the idle workers' thread
            // budget across the solves actually in flight, remainder to
            // the earliest keys (results are bit-identical for every
            // thread count, so this is invisible to the cache). With
            // ≥ workers keys the share floors at the configured per-solve
            // count, keeping the concurrent total within the budget.
            let base_threads = options.resolved_threads();
            let budget = workers * base_threads;
            let share = budget / inputs.len().max(1);
            let extra = budget % inputs.len().max(1);
            let solved = ordered_map(&inputs, workers, |i, inp| {
                let per_solve = (share + usize::from(i < extra)).max(base_threads);
                // A request whose deadline expired while queued is
                // answered without burning a solve: Interrupted (counted
                // in `errors`, so the accounting invariant stays exact),
                // never NoFeasibleMapping — queueing delay proves nothing
                // about the key.
                let outcome = match effective_options(options, inp.3) {
                    // With `with_shards(n ≥ 2)`, fan the miss across
                    // worker processes (DESIGN.md §10): same options,
                    // seed, and per-solve thread share, and a merged
                    // answer bit-identical to the in-process route — so
                    // the cache and warm store never observe which ran.
                    Some(opts) => match &dist {
                        Some(d) => {
                            let opts = SolverOptions { solve_threads: per_solve, ..opts };
                            match solve_dist(inp.0, &inp.1, opts, inp.2, d) {
                                Ok(r) => {
                                    m.shard_solves.fetch_add(1, Ordering::Relaxed);
                                    m.shard_retries
                                        .fetch_add(r.certificate.shard_retries, Ordering::Relaxed);
                                    m.shard_respawns
                                        .fetch_add(r.certificate.shard_respawns, Ordering::Relaxed);
                                    m.breaker_trips
                                        .fetch_add(r.certificate.breaker_trips, Ordering::Relaxed);
                                    // Latch: open while the latest dist
                                    // solve tripped its spawn breaker,
                                    // clear on the next clean one — the
                                    // readiness probe's view of fleet
                                    // health (DESIGN.md §13).
                                    m.breaker_open.store(
                                        r.certificate.breaker_trips > 0,
                                        Ordering::Relaxed,
                                    );
                                    Ok(r)
                                }
                                Err(DistError::Solve(e)) => Err(e),
                                // A fleet failure (spawn/handshake) says
                                // nothing about the key: answer in-process
                                // rather than failing the request.
                                Err(DistError::Worker(_)) => SolveRequest::new(inp.0, &inp.1)
                                    .options(opts)
                                    .threads(per_solve)
                                    .seed(inp.2)
                                    .store(&candidates)
                                    .solve(),
                            }
                        }
                        None => SolveRequest::new(inp.0, &inp.1)
                            .options(opts)
                            .threads(per_solve)
                            .seed(inp.2)
                            .store(&candidates)
                            .solve(),
                    },
                    None => Err(SolveError::Interrupted),
                };
                let result: WarmOutcome = match outcome {
                    Ok(r) => {
                        m.solves.fetch_add(1, Ordering::Relaxed);
                        Ok(Arc::new(r))
                    }
                    Err(e) => {
                        m.errors.fetch_add(1, Ordering::Relaxed);
                        Err(e)
                    }
                };
                let waiters = std::mem::take(&mut *slots[i].lock().unwrap());
                reply_all(waiters, &result, &m);
                result
            });
            for ((fp, afp, had_deadline), result) in keys.into_iter().zip(solved) {
                // Cache only *proved* outcomes. Under a wall-clock cap —
                // the service-wide `time_limit` or a per-request deadline
                // — a NoFeasibleMapping bailout, an Interrupted (timed
                // out with no incumbent), and an unproven incumbent
                // (`proved_optimal == false`) are all load-dependent:
                // caching or persisting any of them would pin a
                // machine-load artifact onto the key forever (DESIGN.md
                // §9). With no cap of either kind NoFeasibleMapping is a
                // proof; a proved-optimal Ok is a proof regardless of
                // what cap it ran under (finishing early is not
                // load-dependent); Interrupted never is.
                let proved = match &result {
                    Ok(r) => r.certificate.proved_optimal,
                    Err(SolveError::NoFeasibleMapping) => {
                        options.time_limit.is_none() && !had_deadline
                    }
                    Err(_) => false,
                };
                if proved {
                    if seed_on {
                        if let Ok(r) = &result {
                            donors.insert(afp, r.mapping);
                        }
                    }
                    // Into the flush window first (the warm store is the
                    // capacity tier — an entry the RAM budget evicts later
                    // still persists), then into the bounded cache.
                    pending.push((fp, WarmEntry { arch_fp: afp, outcome: result.clone() }));
                    cache.insert(fp, CacheEntry { result, arch_fp: afp, warm: false });
                }
            }
        }
        // The crash-safe flush: land the window once it is large or old
        // enough. Proofs answered since the last flush are the only thing
        // a SIGKILL can lose.
        if pending.len() >= flush_every
            || (!pending.is_empty() && last_flush.elapsed() >= flush_interval)
        {
            flush_window(&store, &mut pending, &m);
            last_flush = Instant::now();
        }
    }
    // Pool exit: land the final window. The store's merged view already
    // carries the loaded set and every earlier flush, so this writes the
    // full union even though only the tail is handed over here.
    flush_window(&store, &mut pending, &m);
    // ...then, as the dispatcher's very last act before the receiver drops,
    // drain anything still queued so the gauges stay honest: those waiters
    // get ServiceUnavailable from their dropped reply senders and are
    // un-counted like any unaccepted submission (see
    // [`ServiceHandle::submit`]).
    while let Ok(msg) = rx.try_recv() {
        if let Msg::Solve(_) = msg {
            m.requests.fetch_sub(1, Ordering::Relaxed);
            m.queue_depth.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::solve;

    fn arch() -> Accelerator {
        Accelerator::custom("svc", 1 << 16, 16, 64)
    }

    #[test]
    fn service_solves_and_caches() {
        let handle = MappingService::default().spawn();
        let shape = GemmShape::new(64, 64, 64);
        let a = handle.map(shape, arch()).unwrap();
        assert!(a.certificate.proved_optimal);
        let b = handle.map(shape, arch()).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second hit must come from cache");
        let (req, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(req, 2);
        assert_eq!(solves, 1);
        assert_eq!(hits, 1);
        assert_eq!(errs, 0);
    }

    #[test]
    fn concurrent_identical_requests_solve_once() {
        let handle = MappingService::default().with_workers(4).spawn();
        let shape = GemmShape::new(128, 64, 32);
        // Submit all eight before waiting: they land in one batch window or
        // hit the cache — either way exactly one solve happens.
        let pendings: Vec<_> = (0..8).map(|_| handle.submit(shape, arch())).collect();
        for p in pendings {
            assert!(p.wait().is_ok());
        }
        let (_, solves, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 1, "identical requests must solve exactly once");
    }

    #[test]
    fn distinct_requests_all_solve() {
        let handle = MappingService::default().with_workers(2).spawn();
        let shapes = [
            GemmShape::new(32, 32, 32),
            GemmShape::new(64, 32, 32),
            GemmShape::new(32, 64, 32),
        ];
        let pendings: Vec<_> = shapes.iter().map(|&s| handle.submit(s, arch())).collect();
        for p in pendings {
            assert!(p.wait().is_ok());
        }
        let (_, solves, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 3);
    }

    #[test]
    fn infeasible_request_reports_error() {
        let handle = MappingService::default().spawn();
        // 7 PEs cannot split over 4×4×4.
        let bad = Accelerator::custom("bad", 2048, 7, 16);
        let err = handle.map(GemmShape::new(4, 4, 4), bad).unwrap_err();
        assert_eq!(err, SolveError::NoFeasibleMapping);
        let (.., errs) = handle.metrics().snapshot();
        assert_eq!(errs, 1);
    }

    #[test]
    fn infeasible_outcome_is_negative_cached() {
        let handle = MappingService::default().spawn();
        let bad = Accelerator::custom("bad", 2048, 7, 16);
        for _ in 0..3 {
            let err = handle.map(GemmShape::new(4, 4, 4), bad.clone()).unwrap_err();
            assert_eq!(err, SolveError::NoFeasibleMapping);
        }
        let (req, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(req, 3);
        assert_eq!(errs, 1, "exactly one solve attempt for a repeated infeasible key");
        assert_eq!(solves, 0);
        assert_eq!(hits, 2);
        assert_eq!(handle.metrics().negative_hits(), 2);
    }

    #[test]
    fn time_limited_bailout_is_not_negative_cached() {
        // Under a wall-clock cap every outcome is load-dependent — an Err
        // bailout on a feasible key, or an unproven incumbent — so neither
        // may poison the cache: every submission re-attempts the solve.
        let opts = SolverOptions {
            time_limit: Some(std::time::Duration::from_nanos(1)),
            ..SolverOptions::default()
        };
        let handle = MappingService::new(opts).spawn();
        let big = Accelerator::custom("cap", 1 << 20, 256, 64);
        let shape = GemmShape::new(1 << 10, 1 << 10, 1 << 10);
        for _ in 0..2 {
            let _ = handle.map(shape, big.clone());
        }
        let (_, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(hits, 0, "a capped bailout must not be served from cache");
        assert_eq!(solves + errs, 2, "every submission must re-attempt the solve");
    }

    #[test]
    fn interrupted_bailout_is_answered_but_never_cached() {
        // Regression for the load-artifact-as-proof bug: a timed-out solve
        // with no incumbent surfaces as Interrupted (the key is perfectly
        // feasible), is answered, and is never cached — every submission
        // re-attempts the solve.
        let opts = SolverOptions {
            time_limit: Some(std::time::Duration::from_nanos(1)),
            ..SolverOptions::default()
        };
        let handle = MappingService::new(opts).spawn();
        let big = Accelerator::custom("cap", 1 << 20, 256, 64);
        let shape = GemmShape::new(1 << 10, 1 << 10, 1 << 10);
        for _ in 0..3 {
            let err = handle.map(shape, big.clone()).unwrap_err();
            assert_eq!(err, SolveError::Interrupted, "feasible key must not be proved out");
        }
        let (req, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(req, 3);
        assert_eq!(hits, 0, "an Interrupted bailout must never be served from cache");
        assert_eq!(handle.metrics().negative_hits(), 0);
        assert_eq!(solves + errs, 3, "every submission must re-attempt the solve");
    }

    #[test]
    fn expired_deadline_is_interrupted_and_never_cached() {
        let handle = MappingService::default().spawn();
        let shape = GemmShape::new(64, 64, 64);
        // A deadline that is already due when the worker picks the request
        // up: the solve must not start, and the waiter gets Interrupted —
        // queueing delay proves nothing about the key.
        let err = handle
            .submit_with_deadline(shape, arch(), Some(Instant::now()))
            .wait()
            .unwrap_err();
        assert_eq!(err, SolveError::Interrupted);
        // The key is not poisoned: a fresh no-deadline submission solves.
        let ok = handle.map(shape, arch()).unwrap();
        assert!(ok.certificate.proved_optimal);
        let (req, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(req, 2);
        assert_eq!(errs, 1, "the expired request counts as an error");
        assert_eq!(solves, 1);
        assert_eq!(hits, 0, "an expired-deadline outcome must never be cached");
    }

    #[test]
    fn generous_deadline_answer_is_bit_identical_and_cached_as_a_proof() {
        let shape = GemmShape::new(64, 96, 32);
        let plain = MappingService::default().spawn().map(shape, arch()).unwrap();
        let handle = MappingService::default().spawn();
        let deadline = Instant::now() + std::time::Duration::from_secs(300);
        let capped = handle
            .submit_with_deadline(shape, arch(), Some(deadline))
            .wait()
            .unwrap();
        assert_eq!(capped.mapping, plain.mapping);
        assert_eq!(capped.energy.normalized.to_bits(), plain.energy.normalized.to_bits());
        assert!(capped.certificate.proved_optimal);
        // A proved optimum is a proof no matter what cap it ran under, so
        // it is cacheable even though a deadline applied (DESIGN.md §9).
        let again = handle.map(shape, arch()).unwrap();
        assert!(Arc::ptr_eq(&capped, &again), "the proof must be served from cache");
        let (_, solves, hits, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 1);
        assert_eq!(hits, 1);
    }

    #[test]
    fn solve_threads_budget_split_is_invisible_to_results() {
        // A lone in-flight key receives the whole workers × solve_threads
        // budget; the answer must still be bit-identical to the serial
        // single-worker service.
        let shape = GemmShape::new(128, 64, 32);
        let serial = MappingService::default().spawn();
        let wide = MappingService::default().with_workers(4).with_solve_threads(2).spawn();
        let a = serial.map(shape, arch()).unwrap();
        let b = wide.map(shape, arch()).unwrap();
        assert_eq!(a.mapping, b.mapping);
        assert_eq!(a.energy.normalized.to_bits(), b.energy.normalized.to_bits());
        assert_eq!(a.certificate.nodes, b.certificate.nodes);
        assert_eq!(a.certificate.combos_pruned, b.certificate.combos_pruned);
    }

    #[test]
    fn fingerprint_ignores_solve_threads() {
        // Thread budgets must share cache entries: the engine's result is
        // bit-identical for every thread count, so the knob never splits
        // the warm store.
        let shape = GemmShape::new(8, 8, 8);
        let a = Accelerator::custom("t", 4096, 8, 32);
        let one = SolverOptions { solve_threads: 1, ..SolverOptions::default() };
        let four = SolverOptions { solve_threads: 4, ..SolverOptions::default() };
        assert_eq!(solve_fingerprint(shape, &a, one), solve_fingerprint(shape, &a, four));
    }

    #[test]
    fn fingerprint_ignores_seed_bounds() {
        // Seeded and unseeded deployments must share cache entries:
        // mappings and energies are bit-identical either way, so the knob
        // never splits the warm store.
        let shape = GemmShape::new(8, 8, 8);
        let a = Accelerator::custom("t", 4096, 8, 32);
        let on = SolverOptions { seed_bounds: Some(true), ..SolverOptions::default() };
        let off = SolverOptions { seed_bounds: Some(false), ..SolverOptions::default() };
        assert_eq!(solve_fingerprint(shape, &a, on), solve_fingerprint(shape, &a, off));
        assert_eq!(
            arch_options_fingerprint(&a, on),
            arch_options_fingerprint(&a, off)
        );
    }

    #[test]
    fn fingerprint_ignores_simd_and_suffix_bounds() {
        // Kernel configuration is a latency knob with a bit-identical
        // answer (DESIGN.md §11): a scalar deployment and an AVX2 one
        // must share cache entries.
        let shape = GemmShape::new(8, 8, 8);
        let a = Accelerator::custom("t", 4096, 8, 32);
        let base = SolverOptions::default();
        for opts in [
            SolverOptions { simd: Some(true), ..base },
            SolverOptions { simd: Some(false), ..base },
            SolverOptions { suffix_bounds: Some(true), ..base },
            SolverOptions { suffix_bounds: Some(false), ..base },
            SolverOptions { simd: Some(false), suffix_bounds: Some(false), ..base },
        ] {
            assert_eq!(
                solve_fingerprint(shape, &a, opts),
                solve_fingerprint(shape, &a, base),
                "{opts:?}"
            );
            assert_eq!(
                arch_options_fingerprint(&a, opts),
                arch_options_fingerprint(&a, base),
                "{opts:?}"
            );
        }
    }

    #[test]
    fn fingerprint_ignores_cache_budget() {
        // A memory budget decides which proved outcomes stay resident,
        // never what an answer is — budgeted and unbounded deployments
        // must share cache entries (DESIGN.md §12).
        let shape = GemmShape::new(8, 8, 8);
        let a = Accelerator::custom("t", 4096, 8, 32);
        let base = SolverOptions::default();
        for opts in [
            SolverOptions { cache_budget_bytes: Some(0), ..base },
            SolverOptions { cache_budget_bytes: Some(64 << 10), ..base },
            SolverOptions { cache_budget_bytes: Some(u64::MAX), ..base },
        ] {
            assert_eq!(
                solve_fingerprint(shape, &a, opts),
                solve_fingerprint(shape, &a, base),
                "{opts:?}"
            );
            assert_eq!(
                arch_options_fingerprint(&a, opts),
                arch_options_fingerprint(&a, base),
                "{opts:?}"
            );
        }
    }

    #[test]
    fn donor_registry_bounds_arch_pools_with_lru() {
        use crate::mapping::{Axis, Bypass, Tile};
        let mk = |x: u64| Mapping {
            l1: Tile::new(x, 1, 1),
            l2: Tile::new(1, 1, 1),
            l3: Tile::new(1, 1, 1),
            alpha01: Axis::X,
            alpha12: Axis::Y,
            b1: Bypass::ALL,
            b3: Bypass::ALL,
        };
        let mut reg = DonorRegistry::new(2);
        reg.insert(10, mk(1));
        reg.insert(20, mk(2));
        assert_eq!(reg.arches(), 2);
        // Touch arch 10 so 20 is the LRU victim when 30 arrives.
        assert_eq!(reg.donors(10).len(), 1);
        reg.insert(30, mk(3));
        assert_eq!(reg.arches(), 2, "a new arch past the cap must evict, not grow");
        assert!(reg.donors(20).is_empty(), "the LRU arch pool is the one dropped");
        assert_eq!(reg.donors(10).len(), 1);
        assert_eq!(reg.donors(30).len(), 1);
        // Inserting for a retained arch promotes it, never evicts it.
        reg.insert(10, mk(4));
        assert_eq!(reg.donors(10).len(), 2);
        assert_eq!(reg.arches(), 2);
    }

    #[test]
    fn tiny_cache_budget_changes_hit_rates_never_answers() {
        // A budget too small to retain anything: every repeat re-solves,
        // and every answer is bit-identical to the unbounded service's.
        // Seeding off so even the effort counters must match exactly (a
        // seeded re-solve could legitimately expand fewer nodes).
        let unbounded = MappingService::default().with_seed_bounds(false).spawn();
        let tiny = MappingService::default()
            .with_seed_bounds(false)
            .with_cache_budget(1)
            .spawn();
        let shapes = [
            GemmShape::new(32, 32, 32),
            GemmShape::new(64, 32, 32),
            GemmShape::new(32, 32, 32),
        ];
        for &s in &shapes {
            let a = unbounded.map(s, arch()).unwrap();
            let b = tiny.map(s, arch()).unwrap();
            assert_eq!(a.mapping, b.mapping, "{s}");
            assert_eq!(a.energy.normalized.to_bits(), b.energy.normalized.to_bits(), "{s}");
            assert_eq!(a.certificate.nodes, b.certificate.nodes, "{s}");
        }
        let (req, solves, hits, _, errs) = tiny.metrics().snapshot();
        assert_eq!(req, 3);
        assert_eq!(hits, 0, "nothing can be retained under a 1-byte budget");
        assert_eq!(solves, 3, "the repeat must re-solve");
        assert_eq!(errs, 0);
        assert!(tiny.metrics().cache_evictions() >= 1, "refusals must be visible");
        assert_eq!(tiny.metrics().cache_bytes(), 0);
        let (_, u_solves, u_hits, ..) = unbounded.metrics().snapshot();
        assert_eq!(u_solves, 2);
        assert_eq!(u_hits, 1);
    }

    #[test]
    fn fingerprint_composes_from_the_arch_half() {
        let shape = GemmShape::new(16, 8, 8);
        let a = Accelerator::custom("t", 4096, 8, 32);
        let o = SolverOptions::default();
        assert_eq!(
            solve_fingerprint(shape, &a, o),
            shape_fingerprint(arch_options_fingerprint(&a, o), shape)
        );
    }

    #[test]
    fn donor_pool_dedups_and_replaces_oldest_when_full() {
        use crate::mapping::{Axis, Bypass, Tile};
        let mk = |x: u64| Mapping {
            l1: Tile::new(x, 1, 1),
            l2: Tile::new(1, 1, 1),
            l3: Tile::new(1, 1, 1),
            alpha01: Axis::X,
            alpha12: Axis::Y,
            b1: Bypass::ALL,
            b3: Bypass::ALL,
        };
        let mut pool = DonorPool::default();
        for x in 0..MAX_DONORS_PER_ARCH as u64 {
            pool.insert(mk(x));
            pool.insert(mk(x)); // duplicate: must not double-insert
        }
        assert_eq!(pool.items.len(), MAX_DONORS_PER_ARCH);
        // Full: the next fresh donor replaces the oldest slot, not nothing.
        let fresh = mk(MAX_DONORS_PER_ARCH as u64);
        pool.insert(fresh);
        assert_eq!(pool.items.len(), MAX_DONORS_PER_ARCH);
        assert!(pool.items.contains(&fresh), "a full pool must admit fresh donors");
        assert!(!pool.items.contains(&mk(0)), "the oldest donor is the one replaced");
    }

    #[test]
    fn sequential_related_solves_seed_and_stay_bit_identical() {
        // A solved first; its winning mapping is a valid donor for the
        // doubled shape B (tiles of 32 divide 64), so B's solve runs
        // seeded — and must still return exactly the unseeded service's
        // answer, with node counters only shrinking.
        let a_shape = GemmShape::new(32, 32, 32);
        let b_shape = GemmShape::new(64, 64, 64);
        let on = MappingService::default().with_seed_bounds(true).spawn();
        let off = MappingService::default().with_seed_bounds(false).spawn();
        let (a_on, b_on) = (on.map(a_shape, arch()).unwrap(), on.map(b_shape, arch()).unwrap());
        let a_off = off.map(a_shape, arch()).unwrap();
        let b_off = off.map(b_shape, arch()).unwrap();
        assert_eq!(on.metrics().seeded_solves(), 1, "B must have been seeded");
        assert!(on.metrics().seed_accepted() >= 1);
        assert_eq!(off.metrics().seeded_solves(), 0);
        assert_eq!(a_on.mapping, a_off.mapping);
        assert_eq!(b_on.mapping, b_off.mapping);
        assert_eq!(b_on.energy.normalized.to_bits(), b_off.energy.normalized.to_bits());
        assert_eq!(b_on.energy.total_pj.to_bits(), b_off.energy.total_pj.to_bits());
        assert!(
            b_on.certificate.nodes <= b_off.certificate.nodes,
            "seeding expanded more nodes ({} > {})",
            b_on.certificate.nodes,
            b_off.certificate.nodes
        );
        assert!(b_on.certificate.proved_optimal);
    }

    #[test]
    fn cache_key_covers_full_arch_parameters_not_name() {
        // Regression: the old key hashed `arch.name` only, so two same-name
        // instances with different SRAM/PE/RF silently returned each
        // other's cached mappings. Under the fingerprint key they must each
        // solve.
        let handle = MappingService::default().spawn();
        let shape = GemmShape::new(64, 64, 64);
        let big = Accelerator::custom("twin", 1 << 16, 16, 64);
        let small = Accelerator::custom("twin", 1 << 12, 8, 16);
        let a = handle.map(shape, big).unwrap();
        let b = handle.map(shape, small).unwrap();
        let (_, solves, hits, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 2, "same-name archs with different params must not alias");
        assert_eq!(hits, 0);
        // exact_pe forces PEs-used == num_pe, so the mappings provably differ.
        assert_eq!(a.mapping.pes_used(), 16);
        assert_eq!(b.mapping.pes_used(), 8);
    }

    #[test]
    fn fingerprint_covers_params_and_options_but_not_name() {
        let shape = GemmShape::new(8, 8, 8);
        let o = SolverOptions::default();
        let a = Accelerator::custom("name-one", 4096, 8, 32);
        let b = Accelerator::custom("name-two", 4096, 8, 32);
        assert_eq!(
            solve_fingerprint(shape, &a, o),
            solve_fingerprint(shape, &b, o),
            "the name must not enter the key"
        );
        let c = Accelerator::custom("name-one", 8192, 8, 32);
        assert_ne!(solve_fingerprint(shape, &a, o), solve_fingerprint(shape, &c, o));
        assert_ne!(
            solve_fingerprint(shape, &a, o),
            solve_fingerprint(GemmShape::new(8, 8, 16), &a, o)
        );
        let relaxed = SolverOptions { exact_pe: false, ..SolverOptions::default() };
        assert_ne!(solve_fingerprint(shape, &a, o), solve_fingerprint(shape, &a, relaxed));
        let capped = SolverOptions {
            time_limit: Some(std::time::Duration::from_secs(1)),
            ..SolverOptions::default()
        };
        assert_ne!(solve_fingerprint(shape, &a, o), solve_fingerprint(shape, &a, capped));
    }

    #[test]
    fn dead_service_is_unavailable_not_infeasible() {
        // Unit level: a reply channel dropped without an answer.
        let (tx, rx) = channel::<WarmOutcome>();
        drop(tx);
        assert_eq!(Pending { rx }.wait().unwrap_err(), SolveError::ServiceUnavailable);
        // Full path: a surviving clone submitting after shutdown.
        let handle = MappingService::default().spawn();
        let survivor = handle.clone();
        handle.shutdown();
        assert_eq!(
            survivor.map(GemmShape::new(32, 32, 32), arch()).unwrap_err(),
            SolveError::ServiceUnavailable
        );
    }

    #[test]
    fn batch_api_answers_in_order_and_coalesces() {
        let handle = MappingService::default().with_workers(4).spawn();
        let s1 = GemmShape::new(32, 32, 32);
        let s2 = GemmShape::new(64, 32, 32);
        let s3 = GemmShape::new(32, 64, 64);
        let shapes = [s1, s2, s1, s3, s2, s1];
        let results: Vec<_> = handle
            .submit_batch(&arch(), &shapes)
            .into_iter()
            .map(|p| p.wait().unwrap())
            .collect();
        for (shape, r) in shapes.iter().zip(&results) {
            let direct = solve(*shape, &arch(), SolverOptions::default()).unwrap();
            assert_eq!(r.mapping, direct.mapping, "answer out of order for {shape}");
            assert_eq!(r.energy.normalized.to_bits(), direct.energy.normalized.to_bits());
        }
        let (req, solves, hits, coalesced, errs) = handle.metrics().snapshot();
        assert_eq!(req, 6);
        assert_eq!(solves, 3, "three distinct keys");
        assert_eq!(errs, 0);
        assert_eq!(req, hits + coalesced + solves + errs, "metrics accounting must sum");
        assert_eq!(handle.metrics().queue_depth(), 0);
        assert_eq!(
            handle.metrics().per_shard_hits().iter().sum::<u64>(),
            hits,
            "per-shard hits must sum to the total"
        );
    }
}
