//! The mapping service: queueing, coalescing, caching, metrics.
//!
//! Thread-based (the offline registry has no async runtime): a dedicated
//! service thread owns the result cache and drains the request queue in
//! batches, so duplicate in-flight requests coalesce into a single solve.
//! Handles are cheap clones; the service thread exits when every handle is
//! dropped.

use crate::arch::Accelerator;
use crate::mapping::GemmShape;
use crate::solver::{solve, SolveError, SolveResult, SolverOptions};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// Cache/coalescing key: a workload shape on a named hardware instance.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Key {
    shape: GemmShape,
    arch: String,
}

struct Request {
    shape: GemmShape,
    arch: Accelerator,
    reply: Sender<Result<Arc<SolveResult>, SolveError>>,
}

/// Service counters (exposed for the CLI's `serve` output and tests).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub solves: AtomicU64,
    pub cache_hits: AtomicU64,
    pub coalesced: AtomicU64,
    pub errors: AtomicU64,
}

impl ServiceMetrics {
    /// `(requests, solves, cache_hits, coalesced, errors)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.requests.load(Ordering::Relaxed),
            self.solves.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.coalesced.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
        )
    }
}

/// A pending reply that can be waited on (futures-lite, std-only).
pub struct Pending {
    rx: Receiver<Result<Arc<SolveResult>, SolveError>>,
}

impl Pending {
    /// Block until the mapping is solved (or fails).
    pub fn wait(self) -> Result<Arc<SolveResult>, SolveError> {
        self.rx.recv().unwrap_or(Err(SolveError::NoFeasibleMapping))
    }
}

/// Client handle: cheap to clone, submits mapping requests.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: Sender<Request>,
    metrics: Arc<ServiceMetrics>,
}

impl ServiceHandle {
    /// Submit a request; returns a [`Pending`] so callers can batch many
    /// submissions before waiting (in-flight duplicates coalesce).
    pub fn submit(&self, shape: GemmShape, arch: Accelerator) -> Pending {
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = channel();
        // A send error means the service thread is gone; the Pending will
        // then yield NoFeasibleMapping from the dropped channel.
        let _ = self.tx.send(Request { shape, arch, reply });
        Pending { rx }
    }

    /// Convenience: submit and wait.
    pub fn map(&self, shape: GemmShape, arch: Accelerator) -> Result<Arc<SolveResult>, SolveError> {
        self.submit(shape, arch).wait()
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }
}

/// The mapping service: owns the cache, drains the queue in batches.
#[derive(Default)]
pub struct MappingService {
    options: SolverOptions,
}

impl MappingService {
    pub fn new(options: SolverOptions) -> Self {
        MappingService { options }
    }

    /// Spawn the service thread; returns the client handle. The thread
    /// exits when every handle is dropped.
    pub fn spawn(self) -> ServiceHandle {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(ServiceMetrics::default());
        let m = metrics.clone();
        let options = self.options;
        std::thread::spawn(move || {
            let mut cache: HashMap<Key, Arc<SolveResult>> = HashMap::new();
            while let Ok(first) = rx.recv() {
                // Drain whatever is queued behind the first request: the
                // batch window in which identical keys coalesce.
                let mut batch = vec![first];
                while let Ok(r) = rx.try_recv() {
                    batch.push(r);
                }
                // Group by key so each distinct (shape, arch) solves once.
                let mut groups: HashMap<Key, Vec<Request>> = HashMap::new();
                for r in batch {
                    let key = Key {
                        shape: r.shape,
                        arch: r.arch.name.clone(),
                    };
                    groups.entry(key).or_default().push(r);
                }
                for (key, waiters) in groups {
                    if waiters.len() > 1 {
                        m.coalesced
                            .fetch_add(waiters.len() as u64 - 1, Ordering::Relaxed);
                    }
                    let result = match cache.get(&key) {
                        Some(r) => {
                            m.cache_hits
                                .fetch_add(waiters.len() as u64, Ordering::Relaxed);
                            Ok(r.clone())
                        }
                        None => {
                            m.solves.fetch_add(1, Ordering::Relaxed);
                            match solve(key.shape, &waiters[0].arch, options) {
                                Ok(r) => {
                                    let arc = Arc::new(r);
                                    cache.insert(key, arc.clone());
                                    Ok(arc)
                                }
                                Err(e) => {
                                    m.errors.fetch_add(1, Ordering::Relaxed);
                                    Err(e)
                                }
                            }
                        }
                    };
                    for w in waiters {
                        let _ = w.reply.send(result.clone());
                    }
                }
            }
        });
        ServiceHandle { tx, metrics }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> Accelerator {
        Accelerator::custom("svc", 1 << 16, 16, 64)
    }

    #[test]
    fn service_solves_and_caches() {
        let handle = MappingService::default().spawn();
        let shape = GemmShape::new(64, 64, 64);
        let a = handle.map(shape, arch()).unwrap();
        assert!(a.certificate.proved_optimal);
        let b = handle.map(shape, arch()).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second hit must come from cache");
        let (req, solves, hits, _, errs) = handle.metrics().snapshot();
        assert_eq!(req, 2);
        assert_eq!(solves, 1);
        assert_eq!(hits, 1);
        assert_eq!(errs, 0);
    }

    #[test]
    fn concurrent_identical_requests_solve_once() {
        let handle = MappingService::default().spawn();
        let shape = GemmShape::new(128, 64, 32);
        // Submit all eight before waiting: they land in one batch window or
        // hit the cache — either way exactly one solve happens.
        let pendings: Vec<_> = (0..8).map(|_| handle.submit(shape, arch())).collect();
        for p in pendings {
            assert!(p.wait().is_ok());
        }
        let (_, solves, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 1, "identical requests must solve exactly once");
    }

    #[test]
    fn distinct_requests_all_solve() {
        let handle = MappingService::default().spawn();
        let shapes = [
            GemmShape::new(32, 32, 32),
            GemmShape::new(64, 32, 32),
            GemmShape::new(32, 64, 32),
        ];
        let pendings: Vec<_> = shapes
            .iter()
            .map(|&s| handle.submit(s, arch()))
            .collect();
        for p in pendings {
            assert!(p.wait().is_ok());
        }
        let (_, solves, ..) = handle.metrics().snapshot();
        assert_eq!(solves, 3);
    }

    #[test]
    fn infeasible_request_reports_error() {
        let handle = MappingService::default().spawn();
        // 7 PEs cannot split over 4×4×4.
        let bad = Accelerator::custom("bad", 2048, 7, 16);
        let err = handle.map(GemmShape::new(4, 4, 4), bad).unwrap_err();
        assert_eq!(err, SolveError::NoFeasibleMapping);
        let (.., errs) = handle.metrics().snapshot();
        assert_eq!(errs, 1);
    }
}
