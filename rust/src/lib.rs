//! # GOMA — Geometrically Optimal Mapping via Analytical Modeling
//!
//! Full-stack reproduction of *GOMA: Geometrically Optimal Mapping via
//! Analytical Modeling for Spatial Accelerators* (Yang et al., 2026):
//! a globally optimal GEMM mapping framework for spatial accelerators.
//!
//! GOMA views a GEMM as a 3D compute grid whose three matrices are
//! orthogonal projections; a mapping hierarchically tiles the grid across a
//! five-level memory hierarchy, walks each stage along one axis, and decides
//! per-axis residency/bypass. Cross-level traffic reduces to *projection
//! update counts*, giving an exact closed-form energy objective with O(1)
//! evaluation ([`energy`]), which an exact branch-and-bound ([`solver`])
//! minimizes under capacity/parallelism/divisibility constraints with a
//! verifiable optimality certificate. The solver is layered
//! ([`solver::space`] enumerates the dominance-pruned search space,
//! [`solver::engine`] scans it in parallel) and is bit-identical for every
//! `solve_threads` value, so intra-solve parallelism is a pure latency
//! knob (DESIGN.md §3–§4).
//!
//! The crate also contains everything the paper's evaluation depends on:
//! a Timeloop-lite reference oracle ([`timeloop`]), an Accelergy-lite ERT
//! and the four Table-I templates ([`arch`]), the five baseline mappers
//! ([`mappers`]), the LLM prefill workload suite ([`workloads`]), the
//! 24-case pipeline ([`eval`]), a PJRT runtime for executing AOT-compiled
//! mapped-GEMM kernels ([`runtime`]), and a sharded mapping service with a
//! persistent warm-start cache and cross-shape incumbent seeding for
//! batch solves ([`coordinator`], [`solver::seed`]).
//!
//! ```no_run
//! use goma::{arch, solver, mapping::GemmShape};
//!
//! let shape = GemmShape::mnk(1024, 2048, 2048);
//! let acc = arch::eyeriss_like();
//! let result = solver::solve(shape, &acc, Default::default()).unwrap();
//! assert!(result.certificate.proved_optimal);
//! println!("{}", result.mapping.describe());
//! ```

pub mod arch;
pub mod cli;
pub mod coordinator;
pub mod energy;
pub mod eval;
pub mod experiments;
pub mod mappers;
pub mod mapping;
pub mod runtime;
pub mod solver;
pub mod timeloop;
pub mod util;
pub mod workloads;

// Crate-root conveniences for the hot entry points (the long paths remain
// canonical; these exist so embedding code can `use goma::{solve, ...}`).
pub use solver::{
    solve, solve_with_threads, SeedBound, SharedCandidateStore, SolveError, SolveRequest,
    SolveResult, SolverOptions,
};
