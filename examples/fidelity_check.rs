//! Fidelity spot-check (§IV-G1 in miniature): compare GOMA's closed-form
//! energy against the Timeloop-lite oracle for one GEMM across its
//! tiling–walk–bypass grid, printing the worst mismatches.
//!
//! ```sh
//! cargo run --release --example fidelity_check
//! ```
//! The full 7-GEMM study is `cargo bench --bench fidelity`.

use goma::arch::eyeriss_like;
use goma::experiments::fidelity;

fn main() {
    let arch = eyeriss_like();
    let report = fidelity::study(&arch);

    println!("fidelity over {} mappings:", report.total());
    println!("  exact          : {:.2}%", report.exact_rate() * 100.0);
    println!("  mean rel err   : {:.4}%", report.mean_rel_err() * 100.0);
    println!(
        "  p95 / p99      : {:.4}% / {:.4}%",
        report.err_percentile(95.0) * 100.0,
        report.err_percentile(99.0) * 100.0
    );
    println!("  energy-weighted: {:.4}%", report.energy_weighted_err() * 100.0);

    // Show the tail: the boundary cases where the closed form's folded
    // counting diverges from exact loop-nest counting (§IV-C remark).
    let mut worst: Vec<&fidelity::Sample> = report.samples.iter().collect();
    worst.sort_by(|a, b| b.rel_err().partial_cmp(&a.rel_err()).unwrap());
    println!("\nworst 5 boundary cases (closed form vs oracle, pJ):");
    for s in worst.iter().take(5) {
        println!(
            "  goma {:>14.1}  oracle {:>14.1}  rel err {:.3}%",
            s.goma_pj,
            s.oracle_pj,
            s.rel_err() * 100.0
        );
    }
    println!(
        "\nInterpretation: mismatches are sparse and small — degenerate (bound-1)\n\
         loops let the oracle's reuse analysis compress slightly further than\n\
         the closed form folds (oracle ≤ closed form always; see the\n\
         property_oracle_never_exceeds_closed_form test)."
    );
}
