//! End-to-end driver: the full GOMA stack on a real small workload.
//!
//! This is the repository's composition proof (DESIGN.md §1): all layers
//! working together on LLaMA-3.2-1B 1k-prefill, Eyeriss-like hardware —
//!
//! 1. **workload extraction** — the eight prefill GEMM types with
//!    occurrence weights (paper §V-A1);
//! 2. **L3 coordinator** — the mapping service maps all of them
//!    concurrently (solver pool, dedup, cache) with optimality
//!    certificates;
//! 3. **oracle scoring + Eq. 35 aggregation** — case-level EDP exactly as
//!    the paper reports it, vs. a baseline mapper for context;
//! 4. **runtime** — the AOT prefill-block artifact (L2 JAX + L1 Pallas,
//!    lowered to HLO text at build time) served through PJRT with
//!    batched-request latency/throughput stats.
//!
//! ```sh
//! make artifacts && cargo run --release --example llm_prefill_e2e
//! ```
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use goma::arch::eyeriss_like;
use goma::coordinator::MappingService;
use goma::mappers::{salsa::Salsa, Mapper};
use goma::timeloop::score;
use goma::workloads::edge_workloads;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let arch = eyeriss_like();
    let workload = edge_workloads()
        .into_iter()
        .find(|w| w.name == "LLaMA-3.2-1B(1k)")
        .expect("workload");
    println!("=== GOMA end-to-end: {} on {} ===\n", workload.name, arch.name);

    // ---- 2. coordinator maps the whole prefill graph ---------------------
    // The sharded service: the whole workload goes in as ONE batch call,
    // distinct shapes fan out across the solve pool, duplicates coalesce.
    let workers = goma::util::parallel::default_jobs();
    let handle = MappingService::default().with_workers(workers).spawn();
    let t0 = Instant::now();
    let shapes: Vec<_> = workload.gemms.iter().map(|g| g.shape).collect();
    let pendings = handle.submit_batch(&arch, &shapes);
    let mut edp_case = 0.0;
    let mut energy_case = 0.0;
    println!(
        "{:<14}{:>24}{:>6}{:>12}{:>12}{:>8}",
        "gemm", "shape", "w", "pJ/MAC", "EDP (J*s)", "gap"
    );
    for (g, pending) in workload.gemms.iter().zip(pendings) {
        let r = pending.wait()?;
        assert!(r.certificate.proved_optimal, "{}", g.ty.name());
        assert!(r.certificate.verify(&r.mapping, g.shape, &arch));
        let s = score(&r.mapping, g.shape, &arch, true)?;
        edp_case += g.weight as f64 * s.edp;
        energy_case += g.weight as f64 * s.energy_pj;
        println!(
            "{:<14}{:>24}{:>6}{:>12.4}{:>12.3e}{:>8.0}",
            g.ty.name(),
            format!("{}x{}x{}", g.shape.x, g.shape.y, g.shape.z),
            g.weight,
            r.energy.normalized,
            s.edp,
            r.certificate.gap
        );
    }
    let map_time = t0.elapsed();
    let (req, solves, hits, coalesced, errs) = handle.metrics().snapshot();
    println!(
        "\ncase EDP (Eq. 35): {edp_case:.4e} J*s   case energy: {:.3} mJ",
        energy_case / 1e9
    );
    println!(
        "service: {req} requests -> {solves} solves ({hits} cache hits, \
         {coalesced} coalesced, {errs} errors) in {map_time:?}"
    );

    // ---- 3. context: a strong baseline on the same case ------------------
    let salsa = Salsa::reduced(42);
    let mut salsa_edp = 0.0;
    let t1 = Instant::now();
    for g in &workload.gemms {
        let r = salsa.map(g.shape, &arch).expect("salsa finds a mapping");
        salsa_edp += g.weight as f64 * score(&r.mapping, g.shape, &arch, false)?.edp;
    }
    println!(
        "baseline: SALSA case EDP {salsa_edp:.4e} J*s ({:.2}x GOMA) in {:?}",
        salsa_edp / edp_case,
        t1.elapsed()
    );
    assert!(salsa_edp >= edp_case * 0.999, "optimality violated");

    // ---- 4. serve the AOT prefill block through PJRT ---------------------
    let dir = goma::runtime::artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        println!("\nartifacts/ missing — run `make artifacts` for the runtime leg");
        return Ok(());
    }
    let manifest = goma::runtime::registry_manifest(&dir)?;
    let spec = manifest
        .iter()
        .find(|s| s.name == "prefill_block")
        .expect("prefill_block artifact");
    let mut rt = goma::runtime::Runtime::cpu()?;
    rt.load_hlo_text(&spec.name, &spec.path(&dir))?;
    let dims = &spec.inputs[0];
    let n: i64 = dims.iter().product();
    let requests = 32;
    let mut lat = Vec::with_capacity(requests);
    let mut checksum = 0.0f32;
    for r in 0..requests {
        let x: Vec<f32> = (0..n)
            .map(|i| (((i + r as i64) % 13) as f32 - 6.0) * 0.05)
            .collect();
        let t = Instant::now();
        let out = rt.execute_f32(&spec.name, &[(x, dims.clone())])?;
        lat.push(t.elapsed().as_secs_f64());
        checksum += out[0];
        assert!(out.iter().all(|v| v.is_finite()), "non-finite output");
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat[lat.len() / 2];
    let p95 = lat[(lat.len() * 95 / 100).min(lat.len() - 1)];
    let thr = requests as f64 / lat.iter().sum::<f64>();
    println!(
        "\nruntime: served {requests} prefill-block requests on PJRT-{} \
         (seq 128, hidden 256)\n         p50 {:.2} ms, p95 {:.2} ms, {:.1} req/s, checksum {:.4}",
        rt.platform(),
        p50 * 1e3,
        p95 * 1e3,
        thr,
        checksum
    );
    println!("\nE2E OK: workload -> optimal mappings (certified) -> oracle EDP -> PJRT serving.");
    Ok(())
}
