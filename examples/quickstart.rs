//! Quickstart: solve a globally optimal mapping and (if artifacts are
//! built) execute the matching AOT-compiled kernel through PJRT.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use goma::arch::eyeriss_like;
use goma::mapping::GemmShape;
use goma::solver::{solve, SolverOptions};
use goma::timeloop::score;

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload: one attention projection GEMM of LLaMA-3.2-1B at
    //    1k prefill, on the Eyeriss-like template of Table I.
    let shape = GemmShape::mnk(1024, 2048, 2048);
    let arch = eyeriss_like();
    println!("workload : {shape}");
    println!(
        "arch     : {} (GLB {} KiB, {} PEs, RF {} words/PE)",
        arch.name,
        arch.sram_words / 1024,
        arch.num_pe,
        arch.regfile_words
    );

    // 2. Solve. The result carries a verifiable optimality certificate:
    //    gap == 0 means proved global optimum of Eq. 34.
    let r = solve(shape, &arch, SolverOptions::default())?;
    println!("\nmapping  : {}", r.mapping.describe());
    println!(
        "energy   : {:.4} pJ/MAC  |  src1 {:.4} + src3 {:.4} + src4 {:.4} + mac {:.4}",
        r.energy.normalized, r.energy.src1, r.energy.src3, r.energy.src4, r.energy.compute
    );
    println!(
        "cert     : ub={:.6} lb={:.6} gap={} nodes={} solved in {:?}",
        r.certificate.upper_bound,
        r.certificate.lower_bound,
        r.certificate.gap,
        r.certificate.nodes,
        r.solve_time
    );
    assert!(r.certificate.verify(&r.mapping, shape, &arch));
    println!("verified : certificate re-checked independently OK");

    // 3. Score with the unified oracle (E, T, EDP — §V-A4).
    let s = score(&r.mapping, shape, &arch, true)?;
    println!(
        "\noracle   : E={:.3} uJ  T={:.3} ms  EDP={:.3e} J*s  util={:.0}%",
        s.energy_pj / 1e6,
        s.seconds * 1e3,
        s.edp,
        s.utilization * 100.0
    );

    // 4. Execute the AOT quickstart kernel through PJRT (build-time Python,
    //    request-time Rust) when artifacts are present.
    let dir = goma::runtime::artifacts_dir();
    if dir.join("manifest.tsv").exists() {
        let manifest = goma::runtime::registry_manifest(&dir)?;
        let spec = manifest
            .iter()
            .find(|s| s.name == "quickstart_gemm")
            .expect("quickstart artifact");
        let mut rt = goma::runtime::Runtime::cpu()?;
        rt.load_hlo_text(&spec.name, &spec.path(&dir))?;
        let a: Vec<f32> = (0..64 * 64).map(|i| (i % 9) as f32 * 0.125).collect();
        let b: Vec<f32> = (0..64 * 64).map(|i| (i % 7) as f32 * 0.25).collect();
        let out = rt.execute_f32(&spec.name, &[(a, vec![64, 64]), (b, vec![64, 64])])?;
        println!(
            "\nruntime  : executed '{}' on PJRT-{} -> {} outputs, checksum {:.3}",
            spec.name,
            rt.platform(),
            out.len(),
            out.iter().sum::<f32>()
        );
    } else {
        println!("\nruntime  : artifacts/ missing — run `make artifacts` for the PJRT demo");
    }
    Ok(())
}
