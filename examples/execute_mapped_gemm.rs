//! Solve → execute: close the loop between the L3 solver and the L1 kernel.
//!
//! The GOMA solver picks the optimal SRAM tiling/walking axis for a GEMM;
//! the AOT step bakes mapping-parameterized Pallas kernels into HLO
//! artifacts. This example solves the mapping, picks the artifact variant
//! whose schedule is closest (same shape family), executes it on PJRT, and
//! verifies the numerics against an in-process reference matmul —
//! demonstrating that a mapping is not an abstract cost-model object but an
//! executable schedule.
//!
//! To regenerate artifacts with the exact solver tiles:
//! `GOMA_AOT_MAPPING="l1x,l1y,l1z,alpha" make artifacts`.
//!
//! ```sh
//! make artifacts && cargo run --release --example execute_mapped_gemm
//! ```

use goma::arch::eyeriss_like;
use goma::mapping::GemmShape;
use goma::solver::{solve, SolverOptions};
use std::time::Instant;

fn ref_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let shape = GemmShape::mnk(256, 256, 256);
    let arch = eyeriss_like();

    // 1. Solve the optimal mapping.
    let r = solve(shape, &arch, SolverOptions::default())?;
    println!("solved   : {}", r.mapping.describe());
    println!(
        "           {:.4} pJ/MAC, certificate gap {}, {:?}",
        r.energy.normalized, r.certificate.gap, r.solve_time
    );
    println!(
        "suggested: GOMA_AOT_MAPPING=\"{},{},{},{}\" make artifacts",
        r.mapping.l1.x, r.mapping.l1.y, r.mapping.l1.z, r.mapping.alpha01
    );

    // 2. Find the mapped-GEMM artifact for this shape.
    let dir = goma::runtime::artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.tsv").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let manifest = goma::runtime::registry_manifest(&dir)?;
    let spec = manifest
        .iter()
        .find(|s| {
            s.inputs.len() == 2
                && s.inputs[0] == vec![shape.x as i64, shape.z as i64]
                && s.inputs[1] == vec![shape.z as i64, shape.y as i64]
        })
        .expect("a mapped_gemm artifact matching 256x256x256");
    println!("artifact : {} — {}", spec.name, spec.description);

    // 3. Execute on PJRT and verify numerics.
    let mut rt = goma::runtime::Runtime::cpu()?;
    rt.load_hlo_text(&spec.name, &spec.path(&dir))?;
    let (m, k, n) = (shape.x as usize, shape.z as usize, shape.y as usize);
    let a: Vec<f32> = (0..m * k).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.05).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i * 29 % 19) as f32 - 9.0) * 0.04).collect();
    let t = Instant::now();
    let got = rt.execute_f32(
        &spec.name,
        &[
            (a.clone(), spec.inputs[0].clone()),
            (b.clone(), spec.inputs[1].clone()),
        ],
    )?;
    let exec = t.elapsed();
    let want = ref_matmul(&a, &b, m, k, n);
    let mut max_err = 0f32;
    for (g, w) in got.iter().zip(want.iter()) {
        max_err = max_err.max((g - w).abs() / w.abs().max(1.0));
    }
    println!(
        "executed : {}x{}x{} on PJRT-{} in {exec:?}; max rel err vs reference {max_err:.2e}",
        m, n, k, rt.platform()
    );
    anyhow::ensure!(max_err < 1e-3, "numerics drifted");
    println!("OK: the solved mapping family runs as a real kernel with exact numerics.");
    Ok(())
}
