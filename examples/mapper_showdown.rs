//! Mapper showdown: run GOMA and all five baselines on a single GEMM and
//! print the quality/runtime table — a one-GEMM slice of Fig. 6 + Fig. 8.
//!
//! ```sh
//! cargo run --release --example mapper_showdown [-- <M> <N> <K> <arch>]
//! ```

use goma::arch;
use goma::mappers::{all_baselines, GomaMapper, Mapper};
use goma::mapping::GemmShape;
use goma::timeloop::score;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let shape = if args.len() >= 3 {
        GemmShape::mnk(args[0].parse()?, args[1].parse()?, args[2].parse()?)
    } else {
        GemmShape::mnk(1024, 2048, 2048) // LLaMA-1B(1k) attn_q_proj
    };
    let acc = match args.get(3).map(String::as_str) {
        Some("gemmini") => arch::gemmini_like(),
        Some("a100") => arch::a100_like(),
        Some("tpu") => arch::tpu_v1_like(),
        _ => arch::eyeriss_like(),
    };
    println!("workload: {shape} on {}\n", acc.name);
    println!(
        "{:<18}{:>12}{:>14}{:>14}{:>12}{:>10}",
        "mapper", "pJ/MAC", "EDP (J*s)", "EDP vs GOMA", "time (s)", "evals"
    );

    let goma = GomaMapper::default();
    let gr = goma.map(shape, &acc).expect("GOMA solves");
    let gs = score(&gr.mapping, shape, &acc, true)?;
    println!(
        "{:<18}{:>12.4}{:>14.3e}{:>14.2}{:>12.4}{:>10}",
        "GOMA",
        gs.energy_pj / shape.volume() as f64,
        gs.edp,
        1.0,
        gr.runtime.as_secs_f64(),
        gr.evaluations
    );

    for mapper in all_baselines(2024) {
        match mapper.map(shape, &acc) {
            Some(r) => {
                let s = score(&r.mapping, shape, &acc, false)?;
                println!(
                    "{:<18}{:>12.4}{:>14.3e}{:>14.2}{:>12.4}{:>10}",
                    mapper.name(),
                    s.energy_pj / shape.volume() as f64,
                    s.edp,
                    s.edp / gs.edp,
                    r.runtime.as_secs_f64(),
                    r.evaluations
                );
                assert!(
                    s.energy_pj >= gs.energy_pj * 0.999,
                    "{} beat the certified optimum?!",
                    mapper.name()
                );
            }
            None => println!("{:<18}  (no feasible mapping found)", mapper.name()),
        }
    }
    println!("\nmapping found by GOMA: {}", gr.mapping.describe());
    println!(
        "certificate: gap 0 after {} branch-and-bound nodes — provably optimal (Eq. 34).",
        gr.evaluations
    );
    Ok(())
}
